//! Task-parallel FFT convolution — §IV.A.3, the paper's flagship CPU
//! primitive.
//!
//! The computation is broken into the five task types of Fig. 3 —
//! input-image transforms, kernel transforms, multiply-adds,
//! output-image transforms, and synchronisation tasks that own all
//! allocation — executed in three stages:
//!
//! 1. **Input transforms**: `S·f` independent serial 3D FFTs, any
//!    worker. The sync task then retires the input and takes Õ.
//! 2. **Kernel transforms + multiply-adds**: kernel (j, i) spectra are
//!    computed by *primary* workers (one per chip, each owning a single
//!    ñ-sized buffer — the `T·ñ` of Table II) and their dependent MADs
//!    run **only on workers of the same chip**, accumulating
//!    `Ĩ[s,i]·w̃[j,i]` into `Õ[s,j]`. Scheduling is
//!    highest-priority-first by distance to the DAG sink. Because each
//!    chip owns one buffer, kernels are issued in *waves* — each wave
//!    gives every chip at most one kernel, and its MADs complete before
//!    the chip's buffer is reused. (The paper expresses the same
//!    constraint through DAG dependencies; waves are the barrier-form of
//!    it with identical peak memory.)
//! 3. **Output transforms**: `S·f'` serial inverse FFTs + bias +
//!    transfer function, any worker.
//!
//! Wave assignment gives each chip a disjoint set of output columns per
//! wave, so no two chips ever accumulate into the same `Õ[s,j]` — the
//! races the paper avoids by task dependencies are avoided structurally.
//!
//! All five sync-task allocations (Ĩ, Õ, per-chip primary buffers, the
//! output tensor) are arena takes from the [`ExecCtx`], released at the
//! same points the paper's sync tasks free them; the FFT plan is shared
//! through the process-wide plan cache.

use crate::exec::ExecCtx;
use crate::fft::fft3d::{with_tl_scratch, Fft3};
use crate::fft::fft_optimal_vec3;
use crate::tensor::{Complex32, Shape5, Tensor5};
use crate::util::sendptr::SendPtr;

use super::precomp::{PrecomputedKernels, SpectraLayout};
use super::{conv_out_shape, Activation, Weights};

/// FFT-based convolutional layer, task-parallel variant, transforming
/// every kernel on the fly. See [`conv_fft_tp_with`] for the
/// cached-spectra entry point.
pub fn conv_fft_tp(input: Tensor5, w: &Weights, act: Activation, ctx: &mut ExecCtx<'_>) -> Tensor5 {
    conv_fft_tp_with(input, w, act, ctx, None)
}

/// FFT-based convolutional layer, task-parallel variant. Consumes
/// `input` (the second sync task retires it into the arena).
///
/// When `kernels` holds a [`PrecomputedKernels`] built for this layer's
/// padded FFT shape, stage 2 skips the primary-worker kernel transforms
/// entirely: the per-chip `T·ñ` buffers are never taken and the MAD
/// tasks read the cached `w̃(j,i)` spectra directly. The wave structure
/// (and therefore the per-`Õ[s,j]` accumulation order) is unchanged, so
/// the output is bit-identical to the on-the-fly path. A half-precision
/// cache keeps the per-chip buffers and the primary-task slot, but the
/// primary task becomes an exact widen of the stored f16/bf16 bits
/// instead of a kernel FFT — same waves, same chip locality, same
/// accumulation order, at a fraction of the task cost. A mismatched
/// cache silently falls back to recomputation.
pub fn conv_fft_tp_with(
    input: Tensor5,
    w: &Weights,
    act: Activation,
    ctx: &mut ExecCtx<'_>,
    kernels: Option<&PrecomputedKernels>,
) -> Tensor5 {
    let pool = ctx.pool();
    let ish = input.shape();
    assert_eq!(ish.f, w.f_in, "channel mismatch");
    let osh = conv_out_shape(ish, w.f_out, w.k);
    let n = ish.spatial();
    let padded = fft_optimal_vec3(n);
    let kernels = kernels.filter(|c| c.matches(SpectraLayout::Cpu, padded, w.f_out, w.f_in));
    let plan = ctx.fft3(padded);
    let spec_len = plan.complex_len();
    let chips = pool.topology().chips;

    // ---- Stage 1: input image transform tasks (S·f, any worker) ----
    let csh = Shape5::new(ish.s, ish.f, padded[0], padded[1], plan.zc());
    let mut itrans = ctx.take_c32_raw(csh.len());
    {
        let itp = SendPtr(itrans.as_mut_ptr());
        let input = &input;
        let plan = &*plan;
        pool.scope(|sc| {
            for s in 0..ish.s {
                for i in 0..ish.f {
                    let off = csh.image_offset(s, i);
                    sc.submit(move |_| {
                        let spec = unsafe { itp.slice_mut(off, spec_len) };
                        with_tl_scratch(|tls| plan.forward(input.image(s, i), n, spec, tls));
                    });
                }
            }
        });
    }
    // Sync task 2: retire the input, take the output transforms. Õ is
    // accumulated into by the MAD tasks, so it must come back zeroed
    // (the non-raw take).
    ctx.retire(input);
    let otsh = Shape5::new(ish.s, w.f_out, padded[0], padded[1], plan.zc());
    let mut otrans = ctx.take_c32(otsh.len());

    // ---- Stage 2: kernel transforms (primary-only) + MADs (chip) ----
    {
        // One spectrum buffer per chip — the primary-thread temporaries.
        // With a live f32 kernel cache the transforms are skipped and
        // the buffers never taken (the Table II `T·ñ` term disappears);
        // a half cache keeps them as widen targets.
        let cached_half = kernels.is_some_and(|c| c.precision().is_half());
        let mut bufs: Vec<Vec<Complex32>> = if kernels.is_none() || cached_half {
            (0..chips).map(|_| ctx.take_c32_raw(spec_len)).collect()
        } else {
            Vec::new()
        };
        let total_pairs = w.f_out * w.f_in;
        let col_blocks = w.f_out.div_ceil(chips);
        let itp = SendPtr(itrans.as_mut_ptr());
        let otp = SendPtr(otrans.as_mut_ptr());
        // Waves over (input row i, column block jb). The wave order —
        // and with it the accumulation order into each Õ[s,j] — is the
        // same on the cached and recompute paths, keeping them
        // bit-identical.
        for i in 0..w.f_in {
            for jb in 0..col_blocks {
                // Which (chip, j) pairs are active this wave.
                let active: Vec<(usize, usize)> = (0..chips)
                    .map(|c| (c, jb * chips + c))
                    .filter(|&(_, j)| j < w.f_out)
                    .collect();
                // Kernel transforms: primary workers, one per chip —
                // skipped entirely when f32 spectra are precomputed.
                // A half cache keeps the primary-task slot but widens
                // the stored bits into the chip buffer instead of
                // transforming (same waves, same chip locality).
                if kernels.is_none() || cached_half {
                    let bufp: Vec<SendPtr<Complex32>> =
                        bufs.iter_mut().map(|b| SendPtr(b.as_mut_ptr())).collect();
                    // One cached plan serves both image and kernel
                    // transforms — the twiddle tables are identical for
                    // a given padded size.
                    let kplan = &*plan;
                    pool.scope(|sc| {
                        for &(c, j) in &active {
                            let bp = bufp[c];
                            let prio = (total_pairs - (j * w.f_in + i)) as i64;
                            sc.submit_chip_primary(c, prio, move |_| {
                                let buf = unsafe { bp.slice_mut(0, spec_len) };
                                match kernels {
                                    Some(cache) => cache.widen_spectrum_into(j, i, buf),
                                    None => with_tl_scratch(|tls| {
                                        kplan.forward(w.kernel(j, i), w.k, buf, tls)
                                    }),
                                }
                            });
                        }
                    });
                }
                // Multiply-add tasks: same chip as their kernel's primary
                // (cache hit: same chip the transform would have run on).
                {
                    let bufp: Vec<SendPtr<Complex32>> =
                        bufs.iter_mut().map(|b| SendPtr(b.as_mut_ptr())).collect();
                    pool.scope(|sc| {
                        for &(c, j) in &active {
                            for s in 0..ish.s {
                                let wbuf: &[Complex32] = match kernels {
                                    Some(cache) if !cached_half => cache.spectrum(j, i),
                                    // Half cache or recompute: the chip
                                    // buffer the primary task just
                                    // filled (widened or transformed).
                                    _ => unsafe {
                                        std::slice::from_raw_parts(bufp[c].get(), spec_len)
                                    },
                                };
                                let prio = (total_pairs - (j * w.f_in + i)) as i64;
                                sc.submit_chip(c, prio, move |_| {
                                    let acc = unsafe {
                                        otp.slice_mut(otsh.image_offset(s, j), spec_len)
                                    };
                                    let inp = unsafe {
                                        std::slice::from_raw_parts(
                                            itp.get().add(csh.image_offset(s, i)),
                                            spec_len,
                                        )
                                    };
                                    Fft3::mad_spectra(acc, inp, wbuf);
                                });
                            }
                        }
                    });
                }
            }
        }
        // Sync task 3 (first half): release the primary buffers.
        for b in bufs {
            ctx.put_c32(b);
        }
    }
    // Sync task 3 (second half): release the input transforms; take the
    // output tensor.
    ctx.put_c32(itrans);
    let mut out = ctx.tensor5(osh);

    // ---- Stage 3: output image transform tasks (S·f', any worker) ----
    {
        let crop_off = [w.k[0] - 1, w.k[1] - 1, w.k[2] - 1];
        let crop = [osh.x, osh.y, osh.z];
        let otp = SendPtr(otrans.as_mut_ptr());
        let outp = SendPtr(out.data_mut().as_mut_ptr());
        let img_len = osh.image_len();
        let plan = &*plan;
        pool.scope(|sc| {
            for s in 0..ish.s {
                for j in 0..w.f_out {
                    sc.submit(move |_| {
                        let spec = unsafe { otp.slice_mut(otsh.image_offset(s, j), spec_len) };
                        let img = unsafe { outp.slice_mut(osh.image_offset(s, j), img_len) };
                        with_tl_scratch(|tls| plan.inverse_crop(spec, crop_off, crop, img, tls));
                        let b = w.bias(j);
                        for v in img.iter_mut() {
                            *v = act.apply(*v + b);
                        }
                    });
                }
            }
        });
    }
    // Final sync task releases the output transforms.
    ctx.put_c32(otrans);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv_layer_reference;
    use crate::util::pool::{ChipTopology, TaskPool};
    use crate::util::quick::assert_allclose;

    fn pool(chips: usize, cores: usize) -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips, cores_per_chip: cores })
    }

    #[test]
    fn matches_reference_small() {
        let p = pool(2, 2);
        let mut ctx = ExecCtx::new(&p);
        let input = Tensor5::random(Shape5::new(2, 3, 6, 7, 8), 21);
        let w = Weights::random(4, 3, [3, 2, 3], 22);
        let expect = conv_layer_reference(&input, &w, Activation::Relu);
        let got = conv_fft_tp(input, &w, Activation::Relu, &mut ctx);
        assert_allclose(got.data(), expect.data(), 1e-3, 1e-2, "fft-tp");
    }

    #[test]
    fn large_ffp_batch_config() {
        // The regime the task-parallel algorithm targets: f·S, f'·S ≥
        // worker count.
        let p = pool(2, 2);
        let mut ctx = ExecCtx::new(&p);
        let input = Tensor5::random(Shape5::new(2, 6, 8, 8, 8), 23);
        let w = Weights::random(6, 6, [3, 3, 3], 24);
        let expect = conv_layer_reference(&input, &w, Activation::Relu);
        let got = conv_fft_tp(input, &w, Activation::Relu, &mut ctx);
        assert_allclose(got.data(), expect.data(), 1e-3, 1e-2, "fft-tp large");
    }

    #[test]
    fn single_chip_topology() {
        let p = pool(1, 3);
        let mut ctx = ExecCtx::new(&p);
        let input = Tensor5::random(Shape5::new(1, 4, 7, 7, 7), 25);
        let w = Weights::random(3, 4, [2, 2, 2], 26);
        let expect = conv_layer_reference(&input, &w, Activation::None);
        let got = conv_fft_tp(input, &w, Activation::None, &mut ctx);
        assert_allclose(got.data(), expect.data(), 1e-3, 1e-2, "fft-tp 1chip");
    }

    #[test]
    fn more_chips_than_outputs() {
        let p = pool(4, 1);
        let mut ctx = ExecCtx::new(&p);
        let input = Tensor5::random(Shape5::new(1, 2, 6, 6, 6), 27);
        let w = Weights::random(2, 2, [3, 3, 3], 28);
        let expect = conv_layer_reference(&input, &w, Activation::Relu);
        let got = conv_fft_tp(input, &w, Activation::Relu, &mut ctx);
        assert_allclose(got.data(), expect.data(), 1e-3, 1e-2, "fft-tp 4chip");
    }

    #[test]
    fn property_matches_dp_variant() {
        let p = pool(2, 2);
        let mut ctx = ExecCtx::new(&p);
        crate::util::quick::check_with(
            crate::util::quick::Config { cases: 10, ..Default::default() },
            "fft-tp == fft-dp",
            |g| {
                let s = g.usize(1, 2);
                let fi = g.usize(1, 4);
                let fo = g.usize(1, 4);
                let k = [g.usize(1, 3), g.usize(1, 3), g.usize(1, 3)];
                let n = [
                    k[0] + g.usize(0, 4),
                    k[1] + g.usize(0, 4),
                    k[2] + g.usize(0, 4),
                ];
                let input = Tensor5::random(Shape5::from_spatial(s, fi, n), g.case as u64 + 7);
                let w = Weights::random(fo, fi, k, g.case as u64 + 300);
                let a = {
                    let inp = input.clone_tensor();
                    crate::conv::fft_dp::conv_fft_dp(inp, &w, Activation::Relu, &mut ctx)
                };
                let b = conv_fft_tp(input, &w, Activation::Relu, &mut ctx);
                assert_allclose(b.data(), a.data(), 1e-3, 1e-2, "tp vs dp");
            },
        );
    }
}
