//! FFT convolution, GPU scheme — Algorithm 3 (§IV.B.2).
//!
//! Built on the batched pruned FFT of §III.C ([`BatchedFft3`]): all `f`
//! images of a batch entry (and all `f` kernels of an output map) are
//! transformed as one batch of contiguous 1D FFTs, and the point-wise
//! multiply/accumulate stages are wide data-parallel sweeps — the shape
//! of work a GPU wants. On this testbed the primitive executes on the
//! simulated device (see `crate::device`), preserving Algorithm 3's
//! three-stage structure and its Table II memory behaviour, including
//! the reuse of the FFT scratch `s̃` for the point-wise products.
//!
//! The two batched plans (image-sized and kernel-sized pruning) come
//! from the shared plan cache; Ĩ, Õ, w̃, s̃ and the FFT permute
//! scratches are arena takes from the [`ExecCtx`].

use crate::exec::ExecCtx;
use crate::fft::fft_optimal_vec3;
use crate::tensor::{Complex32, Tensor5};
use crate::util::sendptr::SendPtr;

use super::precomp::{PrecomputedKernels, SpectraLayout};
use super::{conv_out_shape, Activation, Weights};

/// FFT-based convolutional layer, GPU scheme, transforming every kernel
/// batch on the fly. See [`conv_fft_gpu_with`] for the cached-spectra
/// entry point.
pub fn conv_fft_gpu(
    input: Tensor5,
    w: &Weights,
    act: Activation,
    ctx: &mut ExecCtx<'_>,
) -> Tensor5 {
    conv_fft_gpu_with(input, w, act, ctx, None)
}

/// FFT-based convolutional layer, GPU scheme. Consumes `input`.
///
/// When `kernels` holds a [`PrecomputedKernels`] in the batched (GPU)
/// layout for this layer's padded FFT shape, stage 2's per-output-map
/// kernel transforms are skipped: PARALLEL-MULT reads the cached `w̃`
/// slab directly and the `w̃`/permute scratches are never taken. Output
/// is bit-identical to the recompute path; a mismatched cache silently
/// falls back. A half-precision cache takes only the `w̃` slab (no
/// permute scratches) and widens batch `j`'s stored f16/bf16 bits into
/// it — one exact widen per output map instead of one batched kernel
/// FFT.
pub fn conv_fft_gpu_with(
    input: Tensor5,
    w: &Weights,
    act: Activation,
    ctx: &mut ExecCtx<'_>,
    kernels: Option<&PrecomputedKernels>,
) -> Tensor5 {
    let pool = ctx.pool();
    let ish = input.shape();
    assert_eq!(ish.f, w.f_in, "channel mismatch");
    let osh = conv_out_shape(ish, w.f_out, w.k);
    let n = ish.spatial();
    let padded = fft_optimal_vec3(n);
    let kernels = kernels.filter(|c| c.matches(SpectraLayout::Gpu, padded, w.f_out, w.f_in));
    let plan_img = ctx.batched_fft3(n, padded);
    let plan_ker = ctx.batched_fft3(w.k, padded);
    let spec = plan_img.spectrum_len();
    let (s_n, f_in, f_out) = (ish.s, w.f_in, w.f_out);

    // Stage 1 — transform all input batches (f images at a time). Raw
    // takes throughout: the batched transforms fully overwrite their
    // outputs/scratches, PARALLEL-MULT assigns s̃, and
    // PARALLEL-ACCUMULATE assigns (not accumulates into) Õ.
    let mut itrans = ctx.take_c32_raw(s_n * f_in * spec);
    {
        let mut s1 = ctx.take_c32_raw(plan_img.forward_scratch1_len(f_in));
        let mut s2 = ctx.take_c32_raw(plan_img.forward_scratch2_len(f_in));
        for s in 0..s_n {
            let imgs = &input.data()
                [ish.image_offset(s, 0)..ish.image_offset(s, 0) + f_in * ish.image_len()];
            plan_img.forward_scratch(
                f_in,
                imgs,
                &mut itrans[s * f_in * spec..(s + 1) * f_in * spec],
                &mut s1,
                &mut s2,
                pool,
            );
        }
        ctx.put_c32(s2);
        ctx.put_c32(s1);
    }
    ctx.retire(input);

    // Stage 2 — per output map: batched kernel transform (or the cached
    // w̃ slab), point-wise products into the scratch s̃, accumulate over
    // input maps.
    let mut otrans = ctx.take_c32_raw(s_n * f_out * spec);
    {
        // w̃ and its permute scratches are only needed when the spectra
        // are recomputed per call; a half cache needs just w̃ as the
        // widen target.
        let cached_half = kernels.is_some_and(|c| c.precision().is_half());
        let (mut wtrans, mut k1, mut k2) = if kernels.is_none() {
            (
                ctx.take_c32_raw(f_in * spec),
                ctx.take_c32_raw(plan_ker.forward_scratch1_len(f_in)),
                ctx.take_c32_raw(plan_ker.forward_scratch2_len(f_in)),
            )
        } else if cached_half {
            (ctx.take_c32_raw(f_in * spec), Vec::new(), Vec::new())
        } else {
            (Vec::new(), Vec::new(), Vec::new())
        };
        let mut prod = ctx.take_c32_raw(f_in * spec);
        let klen = w.klen();
        for j in 0..f_out {
            let wt: &[Complex32] = match kernels {
                Some(c) if !cached_half => c.batch(j),
                Some(c) => {
                    c.widen_batch_into(j, &mut wtrans);
                    &wtrans
                }
                None => {
                    let kbatch = &w.raw()[j * f_in * klen..(j + 1) * f_in * klen];
                    plan_ker.forward_scratch(f_in, kbatch, &mut wtrans, &mut k1, &mut k2, pool);
                    &wtrans
                }
            };
            for s in 0..s_n {
                let ibase = s * f_in * spec;
                // PARALLEL-MULT: s̃[i][e] = Ĩ[s,i][e] · w̃[i][e]
                {
                    let pp = SendPtr(prod.as_mut_ptr());
                    let it = &itrans;
                    let total = f_in * spec;
                    let chunks = (pool.workers() * 4).min(total.max(1));
                    let per = total.div_ceil(chunks);
                    pool.parallel_for(chunks, |c| {
                        let lo = c * per;
                        let hi = ((c + 1) * per).min(total);
                        if lo >= hi {
                            return;
                        }
                        let dst = unsafe { pp.slice_mut(lo, hi - lo) };
                        crate::simd::cmul(dst, &it[ibase + lo..ibase + hi], &wt[lo..hi]);
                    });
                }
                // PARALLEL-ACCUMULATE: Õ[s,j][e] = Σ_i s̃[i][e]
                {
                    let ob = (s * f_out + j) * spec;
                    let op = SendPtr(otrans.as_mut_ptr());
                    let pr = &prod;
                    let chunks = (pool.workers() * 4).min(spec.max(1));
                    let per = spec.div_ceil(chunks);
                    pool.parallel_for(chunks, |c| {
                        let lo = c * per;
                        let hi = ((c + 1) * per).min(spec);
                        if lo >= hi {
                            return;
                        }
                        let dst = unsafe { op.slice_mut(ob + lo, hi - lo) };
                        for (o, d) in dst.iter_mut().enumerate() {
                            let e = lo + o;
                            let mut acc = Complex32::ZERO;
                            for i in 0..f_in {
                                acc += pr[i * spec + e];
                            }
                            *d = acc;
                        }
                    });
                }
            }
        }
        ctx.put_c32(k2);
        ctx.put_c32(k1);
        ctx.put_c32(prod);
        ctx.put_c32(wtrans);
    }
    ctx.put_c32(itrans);

    // Stage 3 — batched inverse transforms, crop to the valid region,
    // bias + transfer function.
    let mut out = ctx.tensor5(osh);
    let crop_off = [w.k[0] - 1, w.k[1] - 1, w.k[2] - 1];
    let crop = [osh.x, osh.y, osh.z];
    {
        let mut s1 = ctx.take_c32_raw(plan_img.inverse_scratch1_len(f_out, crop[0], crop[1]));
        let mut s2 = ctx.take_c32_raw(plan_img.inverse_scratch2_len(f_out, crop[0]));
        for s in 0..s_n {
            let ob = s * f_out * spec;
            let img_base = osh.image_offset(s, 0);
            let img_len = f_out * osh.image_len();
            plan_img.inverse_crop_scratch(
                f_out,
                &mut otrans[ob..ob + f_out * spec],
                crop_off,
                crop,
                &mut out.data_mut()[img_base..img_base + img_len],
                &mut s1,
                &mut s2,
                pool,
            );
            for j in 0..f_out {
                let b = w.bias(j);
                for v in out.image_mut(s, j).iter_mut() {
                    *v = act.apply(*v + b);
                }
            }
        }
        ctx.put_c32(s2);
        ctx.put_c32(s1);
    }
    ctx.put_c32(otrans);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv_layer_reference;
    use crate::tensor::Shape5;
    use crate::util::pool::{ChipTopology, TaskPool};
    use crate::util::quick::assert_allclose;

    fn pool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    #[test]
    fn matches_reference_small() {
        let p = pool();
        let mut ctx = ExecCtx::new(&p);
        let input = Tensor5::random(Shape5::new(2, 3, 6, 7, 8), 31);
        let w = Weights::random(4, 3, [3, 2, 3], 32);
        let expect = conv_layer_reference(&input, &w, Activation::Relu);
        let got = conv_fft_gpu(input, &w, Activation::Relu, &mut ctx);
        assert_allclose(got.data(), expect.data(), 1e-3, 1e-2, "gpu-fft");
    }

    #[test]
    fn larger_kernels() {
        let p = pool();
        let mut ctx = ExecCtx::new(&p);
        let input = Tensor5::random(Shape5::new(1, 2, 11, 11, 11), 33);
        let w = Weights::random(3, 2, [5, 5, 5], 34);
        let expect = conv_layer_reference(&input, &w, Activation::Relu);
        let got = conv_fft_gpu(input, &w, Activation::Relu, &mut ctx);
        assert_allclose(got.data(), expect.data(), 1e-3, 1e-2, "gpu-fft k5");
    }

    #[test]
    fn property_matches_reference() {
        let p = pool();
        let mut ctx = ExecCtx::new(&p);
        crate::util::quick::check_with(
            crate::util::quick::Config { cases: 10, ..Default::default() },
            "gpu-fft == reference",
            |g| {
                let s = g.usize(1, 2);
                let fi = g.usize(1, 3);
                let fo = g.usize(1, 3);
                let k = [g.usize(1, 4), g.usize(1, 4), g.usize(1, 4)];
                let n = [
                    k[0] + g.usize(0, 5),
                    k[1] + g.usize(0, 5),
                    k[2] + g.usize(0, 5),
                ];
                let input = Tensor5::random(Shape5::from_spatial(s, fi, n), g.case as u64 + 17);
                let w = Weights::random(fo, fi, k, g.case as u64 + 400);
                let expect = conv_layer_reference(&input, &w, Activation::None);
                let got = conv_fft_gpu(input, &w, Activation::None, &mut ctx);
                assert_allclose(got.data(), expect.data(), 1e-3, 1e-2, "prop gpu-fft");
            },
        );
    }
}
