//! FFT convolution, GPU scheme — Algorithm 3 (§IV.B.2).
//!
//! Built on the batched pruned FFT of §III.C ([`BatchedFft3`]): all `f`
//! images of a batch entry (and all `f` kernels of an output map) are
//! transformed as one batch of contiguous 1D FFTs, and the point-wise
//! multiply/accumulate stages are wide data-parallel sweeps — the shape
//! of work a GPU wants. On this testbed the primitive executes on the
//! simulated device (see `crate::device`), preserving Algorithm 3's
//! three-stage structure and its Table II memory behaviour, including
//! the reuse of the FFT scratch `s̃` for the point-wise products.

use crate::fft::batched::BatchedFft3;
use crate::fft::fft_optimal_vec3;
use crate::memory::TrackedVec;
use crate::tensor::{Complex32, Tensor5};
use crate::util::pool::TaskPool;
use crate::util::sendptr::SendPtr;

use super::{conv_out_shape, Activation, Weights};

/// FFT-based convolutional layer, GPU scheme. Consumes `input`.
pub fn conv_fft_gpu(input: Tensor5, w: &Weights, act: Activation, pool: &TaskPool) -> Tensor5 {
    let ish = input.shape();
    assert_eq!(ish.f, w.f_in, "channel mismatch");
    let osh = conv_out_shape(ish, w.f_out, w.k);
    let n = ish.spatial();
    let padded = fft_optimal_vec3(n);
    let plan_img = BatchedFft3::new(n, padded);
    let plan_ker = BatchedFft3::new(w.k, padded);
    let spec = plan_img.spectrum_len();
    let (s_n, f_in, f_out) = (ish.s, w.f_in, w.f_out);

    // Stage 1 — transform all input batches (f images at a time).
    let mut itrans: TrackedVec<Complex32> = TrackedVec::zeroed(s_n * f_in * spec, "gpu-fft Itilde");
    for s in 0..s_n {
        let imgs = &input.data()
            [ish.image_offset(s, 0)..ish.image_offset(s, 0) + f_in * ish.image_len()];
        plan_img.forward(f_in, imgs, &mut itrans.as_mut_slice()[s * f_in * spec..(s + 1) * f_in * spec], pool);
    }
    drop(input);

    // Stage 2 — per output map: batched kernel transform, point-wise
    // products into the scratch s̃, accumulate over input maps.
    let mut otrans: TrackedVec<Complex32> = TrackedVec::zeroed(s_n * f_out * spec, "gpu-fft Otilde");
    {
        let mut wtrans: TrackedVec<Complex32> = TrackedVec::zeroed(f_in * spec, "gpu-fft wtilde");
        let mut prod: TrackedVec<Complex32> = TrackedVec::zeroed(f_in * spec, "gpu-fft stilde");
        let klen = w.klen();
        for j in 0..f_out {
            let kbatch = &w.raw()[j * f_in * klen..(j + 1) * f_in * klen];
            plan_ker.forward(f_in, kbatch, wtrans.as_mut_slice(), pool);
            for s in 0..s_n {
                let ibase = s * f_in * spec;
                // PARALLEL-MULT: s̃[i][e] = Ĩ[s,i][e] · w̃[i][e]
                {
                    let pp = SendPtr(prod.as_mut_ptr());
                    let it = itrans.as_slice();
                    let wt = wtrans.as_slice();
                    let total = f_in * spec;
                    let chunks = (pool.workers() * 4).min(total.max(1));
                    let per = total.div_ceil(chunks);
                    pool.parallel_for(chunks, |c| {
                        let lo = c * per;
                        let hi = ((c + 1) * per).min(total);
                        if lo >= hi {
                            return;
                        }
                        let dst = unsafe { pp.slice_mut(lo, hi - lo) };
                        crate::simd::cmul(dst, &it[ibase + lo..ibase + hi], &wt[lo..hi]);
                    });
                }
                // PARALLEL-ACCUMULATE: Õ[s,j][e] = Σ_i s̃[i][e]
                {
                    let ob = (s * f_out + j) * spec;
                    let op = SendPtr(otrans.as_mut_ptr());
                    let pr = prod.as_slice();
                    let chunks = (pool.workers() * 4).min(spec.max(1));
                    let per = spec.div_ceil(chunks);
                    pool.parallel_for(chunks, |c| {
                        let lo = c * per;
                        let hi = ((c + 1) * per).min(spec);
                        if lo >= hi {
                            return;
                        }
                        let dst = unsafe { op.slice_mut(ob + lo, hi - lo) };
                        for (o, d) in dst.iter_mut().enumerate() {
                            let e = lo + o;
                            let mut acc = Complex32::ZERO;
                            for i in 0..f_in {
                                acc += pr[i * spec + e];
                            }
                            *d = acc;
                        }
                    });
                }
            }
        }
    }
    drop(itrans);

    // Stage 3 — batched inverse transforms, crop to the valid region,
    // bias + transfer function.
    let mut out = Tensor5::zeros(osh);
    let crop_off = [w.k[0] - 1, w.k[1] - 1, w.k[2] - 1];
    let crop = [osh.x, osh.y, osh.z];
    for s in 0..s_n {
        let ob = s * f_out * spec;
        let img_base = osh.image_offset(s, 0);
        let img_len = f_out * osh.image_len();
        plan_img.inverse_crop(
            f_out,
            &mut otrans.as_mut_slice()[ob..ob + f_out * spec],
            crop_off,
            crop,
            &mut out.data_mut()[img_base..img_base + img_len],
            pool,
        );
        for j in 0..f_out {
            let b = w.bias(j);
            for v in out.image_mut(s, j).iter_mut() {
                *v = act.apply(*v + b);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv_layer_reference;
    use crate::tensor::Shape5;
    use crate::util::pool::ChipTopology;
    use crate::util::quick::assert_allclose;

    fn pool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    #[test]
    fn matches_reference_small() {
        let p = pool();
        let input = Tensor5::random(Shape5::new(2, 3, 6, 7, 8), 31);
        let w = Weights::random(4, 3, [3, 2, 3], 32);
        let expect = conv_layer_reference(&input, &w, Activation::Relu);
        let got = conv_fft_gpu(input, &w, Activation::Relu, &p);
        assert_allclose(got.data(), expect.data(), 1e-3, 1e-2, "gpu-fft");
    }

    #[test]
    fn larger_kernels() {
        let p = pool();
        let input = Tensor5::random(Shape5::new(1, 2, 11, 11, 11), 33);
        let w = Weights::random(3, 2, [5, 5, 5], 34);
        let expect = conv_layer_reference(&input, &w, Activation::Relu);
        let got = conv_fft_gpu(input, &w, Activation::Relu, &p);
        assert_allclose(got.data(), expect.data(), 1e-3, 1e-2, "gpu-fft k5");
    }

    #[test]
    fn property_matches_reference() {
        let p = pool();
        crate::util::quick::check_with(
            crate::util::quick::Config { cases: 10, ..Default::default() },
            "gpu-fft == reference",
            |g| {
                let s = g.usize(1, 2);
                let fi = g.usize(1, 3);
                let fo = g.usize(1, 3);
                let k = [g.usize(1, 4), g.usize(1, 4), g.usize(1, 4)];
                let n = [
                    k[0] + g.usize(0, 5),
                    k[1] + g.usize(0, 5),
                    k[2] + g.usize(0, 5),
                ];
                let input = Tensor5::random(Shape5::from_spatial(s, fi, n), g.case as u64 + 17);
                let w = Weights::random(fo, fi, k, g.case as u64 + 400);
                let expect = conv_layer_reference(&input, &w, Activation::None);
                let got = conv_fft_gpu(input, &w, Activation::None, &p);
                assert_allclose(got.data(), expect.data(), 1e-3, 1e-2, "prop gpu-fft");
            },
        );
    }
}
