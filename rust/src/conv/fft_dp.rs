//! Data-parallel FFT convolution — Algorithm 2 (§IV.A.2).
//!
//! Every computationally intensive operation (each 3D FFT, each inverse
//! FFT, each point-wise multiply-add sweep) is *individually*
//! parallelised across all workers. This variant has lower memory
//! overhead than the task-parallel algorithm (one kernel spectrum and
//! one output accumulator tensor live at a time) but keeps all workers
//! touching shared data — the paper measures it up to 10× slower than
//! the task-parallel algorithm when `f·S` is large, yet it remains the
//! best choice for the first layer where `f = S = 1`.
//!
//! Buffers (input spectra, the Õ accumulator, the w̃ spectrum, the
//! output tensor) are drawn from the [`ExecCtx`] arena and returned at
//! the same points the originals were freed, so ledger peaks match the
//! Table II staging while a warm context re-executes allocation-free.
//! The FFT plan comes from the shared plan cache — one plan serves the
//! image and kernel transforms alike (identical twiddle tables for a
//! given padded size).

use crate::exec::ExecCtx;
use crate::fft::fft3d::Fft3;
use crate::fft::fft_optimal_vec3;
use crate::tensor::{Complex32, Shape5, Tensor5};

use super::precomp::{PrecomputedKernels, SpectraLayout};
use super::{conv_out_shape, Activation, Weights};

/// FFT-based convolutional layer, data-parallel variant, transforming
/// every kernel on the fly. See [`conv_fft_dp_with`] for the
/// cached-spectra entry point.
pub fn conv_fft_dp(input: Tensor5, w: &Weights, act: Activation, ctx: &mut ExecCtx<'_>) -> Tensor5 {
    conv_fft_dp_with(input, w, act, ctx, None)
}

/// FFT-based convolutional layer, data-parallel variant.
///
/// Consumes `input` (Algorithm 2 frees I after the forward transforms —
/// here its backing store is retired into the arena at that point).
/// When `kernels` holds a [`PrecomputedKernels`] built for this layer's
/// padded FFT shape, stage 2 reads the cached `w̃(j,i)` spectra instead
/// of re-transforming each kernel per output map — bit-identical output
/// (the cache was built with the same transform path), minus
/// `f'·f` pruned kernel FFTs per call. A half-precision cache
/// (f16/bf16 storage) is widened into the same `w̃` scratch the
/// recompute path uses — one exact widen per `(j, i)` instead of one
/// kernel FFT, with the multiply-add consuming plain f32 either way. A
/// mismatched cache (different padded shape) silently falls back to
/// on-the-fly transforms.
pub fn conv_fft_dp_with(
    input: Tensor5,
    w: &Weights,
    act: Activation,
    ctx: &mut ExecCtx<'_>,
    kernels: Option<&PrecomputedKernels>,
) -> Tensor5 {
    let pool = ctx.pool();
    let ish = input.shape();
    assert_eq!(ish.f, w.f_in, "channel mismatch");
    let osh = conv_out_shape(ish, w.f_out, w.k);
    let n = ish.spatial();
    let padded = fft_optimal_vec3(n);
    let kernels = kernels.filter(|c| c.matches(SpectraLayout::Cpu, padded, w.f_out, w.f_in));
    let plan = ctx.fft3(padded);
    let zc = plan.zc();
    let spec_len = plan.complex_len();
    let csh = Shape5::new(ish.s, ish.f, padded[0], padded[1], zc);

    // Stage 1 — forward transforms of all input images (each transform
    // internally parallel), then retire the input. Raw takes: forward
    // transforms overwrite the full spectrum, Õ is zero-filled per
    // output map below.
    let mut itrans = ctx.take_c32_raw(csh.len());
    for s in 0..ish.s {
        for i in 0..ish.f {
            let off = csh.image_offset(s, i);
            plan.forward_par(input.image(s, i), n, &mut itrans[off..off + spec_len], pool);
        }
    }
    ctx.retire(input);

    // Stage 2 — for each output map: transform its kernels one at a
    // time (w̃ is a single spectrum buffer) — or read the precomputed
    // spectrum when the cache is live — multiply-add into the per-batch
    // accumulator Õ, then inverse-transform into O.
    let mut out = ctx.tensor5(osh);
    let mut otrans = ctx.take_c32_raw(ish.s * spec_len);
    // The w̃ scratch serves the recompute path (transform target) and
    // the half-precision cache path (widen target); an f32 cache is
    // read in place and never takes it.
    let cached_half = kernels.is_some_and(|c| c.precision().is_half());
    let mut wtrans = if kernels.is_none() || cached_half {
        ctx.take_c32_raw(spec_len)
    } else {
        Vec::new()
    };
    let crop_off = [w.k[0] - 1, w.k[1] - 1, w.k[2] - 1];
    let crop = [osh.x, osh.y, osh.z];
    for j in 0..w.f_out {
        otrans.fill(Complex32::ZERO);
        for i in 0..w.f_in {
            let wspec: &[Complex32] = match kernels {
                Some(c) if !cached_half => c.spectrum(j, i),
                Some(c) => {
                    c.widen_spectrum_into(j, i, &mut wtrans);
                    &wtrans
                }
                None => {
                    plan.forward_par(w.kernel(j, i), w.k, &mut wtrans, pool);
                    &wtrans
                }
            };
            for s in 0..ish.s {
                let acc = &mut otrans[s * spec_len..(s + 1) * spec_len];
                let ioff = csh.image_offset(s, i);
                Fft3::mad_spectra_par(acc, &itrans[ioff..ioff + spec_len], wspec, pool);
            }
        }
        let b = w.bias(j);
        for s in 0..ish.s {
            let acc = &mut otrans[s * spec_len..(s + 1) * spec_len];
            plan.inverse_crop_par(acc, crop_off, crop, out.image_mut(s, j), pool);
            for v in out.image_mut(s, j).iter_mut() {
                *v = act.apply(*v + b);
            }
        }
    }
    ctx.put_c32(wtrans);
    ctx.put_c32(otrans);
    ctx.put_c32(itrans);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv_layer_reference;
    use crate::util::pool::{ChipTopology, TaskPool};
    use crate::util::quick::assert_allclose;

    fn pool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    #[test]
    fn matches_reference_small() {
        let p = pool();
        let mut ctx = ExecCtx::new(&p);
        let input = Tensor5::random(Shape5::new(2, 3, 6, 7, 8), 11);
        let w = Weights::random(4, 3, [3, 2, 3], 12);
        let expect = conv_layer_reference(&input, &w, Activation::Relu);
        let got = conv_fft_dp(input, &w, Activation::Relu, &mut ctx);
        assert_allclose(got.data(), expect.data(), 1e-3, 1e-2, "fft-dp");
    }

    #[test]
    fn first_layer_shape_s1_f1() {
        // The configuration the paper finds FFT-DP optimal for.
        let p = pool();
        let mut ctx = ExecCtx::new(&p);
        let input = Tensor5::random(Shape5::new(1, 1, 12, 12, 12), 13);
        let w = Weights::random(5, 1, [4, 4, 4], 14);
        let expect = conv_layer_reference(&input, &w, Activation::Relu);
        let got = conv_fft_dp(input, &w, Activation::Relu, &mut ctx);
        assert_allclose(got.data(), expect.data(), 1e-3, 1e-2, "fft-dp first layer");
    }

    #[test]
    fn property_matches_reference() {
        let p = pool();
        let mut ctx = ExecCtx::new(&p);
        crate::util::quick::check_with(
            crate::util::quick::Config { cases: 12, ..Default::default() },
            "fft-dp == reference",
            |g| {
                let s = g.usize(1, 2);
                let fi = g.usize(1, 3);
                let fo = g.usize(1, 3);
                let k = [g.usize(1, 4), g.usize(1, 4), g.usize(1, 4)];
                let n = [
                    k[0] + g.usize(0, 5),
                    k[1] + g.usize(0, 5),
                    k[2] + g.usize(0, 5),
                ];
                let input = Tensor5::random(Shape5::from_spatial(s, fi, n), g.case as u64 + 3);
                let w = Weights::random(fo, fi, k, g.case as u64 + 200);
                let expect = conv_layer_reference(&input, &w, Activation::None);
                let got = conv_fft_dp(input, &w, Activation::None, &mut ctx);
                assert_allclose(got.data(), expect.data(), 1e-3, 1e-2, "prop fft-dp");
            },
        );
    }
}
