//! Direct convolution — Algorithm 1 (§IV.A.1).
//!
//! The computation is parallelised by two `parallel for` loops so every
//! output image of every batch entry is produced on its own worker.
//! Two variants:
//!
//! * **naive** — accumulates straight into the output image; minimal
//!   memory (Table II row 1);
//! * **"MKL"** — convolve into a per-worker temporary image, then
//!   accumulate; ~2× faster at the cost of `T·n'` extra elements
//!   (Table II row 2). It mirrors the paper's Intel-MKL-backed
//!   variant, which also trades a temp image for speed. The temporaries
//!   are drawn from the execution context's arena (one per worker, via
//!   [`TaskPool::parallel_for_with_worker`]) instead of allocated per
//!   call.
//!
//! Both share the z-contiguous per-tap multiply-add inner loop, which
//! dispatches through [`crate::simd::axpy`] (AVX2+FMA / SSE2 / NEON /
//! scalar); the scalar six-loop oracle lives in
//! [`super::convolve_valid_accumulate_scalar`].

use crate::exec::ExecCtx;
use crate::tensor::Tensor5;
use crate::util::sendptr::SendPtr;

use super::{conv_out_shape, convolve_valid_accumulate, Activation, Weights};

/// Direct convolutional layer, naive inner loop.
pub fn conv_direct_naive(
    input: &Tensor5,
    w: &Weights,
    act: Activation,
    ctx: &mut ExecCtx<'_>,
) -> Tensor5 {
    let pool = ctx.pool();
    let ish = input.shape();
    assert_eq!(ish.f, w.f_in, "channel mismatch");
    let osh = conv_out_shape(ish, w.f_out, w.k);
    let mut out = ctx.tensor5(osh);
    let outp = SendPtr(out.data_mut().as_mut_ptr());
    let img_len = osh.image_len();
    // parallel over (s, j) pairs — Algorithm 1's two parallel-for loops.
    pool.parallel_for(ish.s * w.f_out, |sj| {
        let (s, j) = (sj / w.f_out, sj % w.f_out);
        let o = unsafe { outp.slice_mut(osh.image_offset(s, j), img_len) };
        for i in 0..w.f_in {
            convolve_valid_accumulate(input.image(s, i), ish.spatial(), w.kernel(j, i), w.k, o);
        }
        let b = w.bias(j);
        for v in o.iter_mut() {
            *v = act.apply(*v + b);
        }
    });
    out
}

/// Direct convolutional layer, optimised ("MKL") inner loop: per-worker
/// temporary image, z-contiguous fused multiply-add over kernel taps.
pub fn conv_direct_mkl(
    input: &Tensor5,
    w: &Weights,
    act: Activation,
    ctx: &mut ExecCtx<'_>,
) -> Tensor5 {
    let pool = ctx.pool();
    let ish = input.shape();
    assert_eq!(ish.f, w.f_in, "channel mismatch");
    let osh = conv_out_shape(ish, w.f_out, w.k);
    let mut out = ctx.tensor5(osh);
    let outp = SendPtr(out.data_mut().as_mut_ptr());
    let img_len = osh.image_len();
    let n = ish.spatial();
    // One temporary image per worker (the T·n' of Table II), drawn from
    // the arena so steady-state calls allocate nothing. A worker runs
    // one job at a time, so indexing by worker id is race-free.
    let mut tmps: Vec<Vec<f32>> =
        (0..pool.workers()).map(|_| ctx.take_f32_raw(img_len)).collect();
    let tmpp: Vec<SendPtr<f32>> = tmps.iter_mut().map(|b| SendPtr(b.as_mut_ptr())).collect();
    {
        let tmpp = &tmpp;
        pool.parallel_for_with_worker(ish.s * w.f_out, |worker, sj| {
            let (s, j) = (sj / w.f_out, sj % w.f_out);
            let o = unsafe { outp.slice_mut(osh.image_offset(s, j), img_len) };
            let tmp = unsafe { tmpp[worker].slice_mut(0, img_len) };
            for i in 0..w.f_in {
                tmp.fill(0.0);
                convolve_valid_accumulate(input.image(s, i), n, w.kernel(j, i), w.k, tmp);
                crate::simd::add_assign(o, tmp);
            }
            let b = w.bias(j);
            for v in o.iter_mut() {
                *v = act.apply(*v + b);
            }
        });
    }
    for t in tmps {
        ctx.put_f32(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv_layer_reference;
    use crate::tensor::Shape5;
    use crate::util::pool::{ChipTopology, TaskPool};
    use crate::util::quick::assert_allclose;

    fn pool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    #[test]
    fn naive_matches_reference() {
        let p = pool();
        let mut ctx = ExecCtx::new(&p);
        let input = Tensor5::random(Shape5::new(2, 3, 6, 7, 8), 1);
        let w = Weights::random(4, 3, [3, 2, 3], 2);
        let expect = conv_layer_reference(&input, &w, Activation::Relu);
        let got = conv_direct_naive(&input, &w, Activation::Relu, &mut ctx);
        assert_allclose(got.data(), expect.data(), 1e-5, 1e-4, "direct naive");
    }

    #[test]
    fn mkl_matches_reference() {
        let p = pool();
        let mut ctx = ExecCtx::new(&p);
        let input = Tensor5::random(Shape5::new(2, 3, 6, 7, 8), 3);
        let w = Weights::random(4, 3, [3, 3, 3], 4);
        let expect = conv_layer_reference(&input, &w, Activation::Relu);
        let got = conv_direct_mkl(&input, &w, Activation::Relu, &mut ctx);
        assert_allclose(got.data(), expect.data(), 1e-5, 1e-4, "direct mkl");
    }

    #[test]
    fn asymmetric_kernels_ok() {
        let p = pool();
        let mut ctx = ExecCtx::new(&p);
        let input = Tensor5::random(Shape5::new(1, 2, 5, 8, 6), 5);
        let w = Weights::random(2, 2, [1, 4, 2], 6);
        let expect = conv_layer_reference(&input, &w, Activation::None);
        for got in [
            conv_direct_naive(&input, &w, Activation::None, &mut ctx),
            conv_direct_mkl(&input, &w, Activation::None, &mut ctx),
        ] {
            assert_allclose(got.data(), expect.data(), 1e-5, 1e-4, "asym");
        }
    }

    #[test]
    fn property_direct_variants_agree() {
        let p = pool();
        let mut ctx = ExecCtx::new(&p);
        crate::util::quick::check("direct naive == mkl", |g| {
            let s = g.usize(1, 2);
            let fi = g.usize(1, 3);
            let fo = g.usize(1, 3);
            let k = [g.usize(1, 3), g.usize(1, 3), g.usize(1, 3)];
            let n = [
                k[0] + g.usize(0, 4),
                k[1] + g.usize(0, 4),
                k[2] + g.usize(0, 4),
            ];
            let input = Tensor5::random(Shape5::from_spatial(s, fi, n), g.case as u64);
            let w = Weights::random(fo, fi, k, g.case as u64 + 100);
            let a = conv_direct_naive(&input, &w, Activation::Relu, &mut ctx);
            let b = conv_direct_mkl(&input, &w, Activation::Relu, &mut ctx);
            assert_allclose(b.data(), a.data(), 1e-5, 1e-4, "variants");
        });
    }
}
