//! Direct convolution — Algorithm 1 (§IV.A.1).
//!
//! The computation is parallelised by two `parallel for` loops so every
//! output image of every batch entry is produced on its own worker.
//! When `S·f'` alone is smaller than the pool (single-image, few-channel
//! layers), each output image is additionally split into x-slabs so the
//! job count covers every worker (`slab_count` / `slab_range` below);
//! slabs write disjoint output rows, so bias+activation stays per-job.
//! Two variants:
//!
//! * **naive** — accumulates straight into the output image; minimal
//!   memory (Table II row 1);
//! * **"MKL"** — convolve into a per-worker temporary image, then
//!   accumulate; ~2× faster at the cost of `T·n'` extra elements
//!   (Table II row 2). It mirrors the paper's Intel-MKL-backed
//!   variant, which also trades a temp image for speed. The temporaries
//!   are drawn from the execution context's arena (one per worker, via
//!   [`TaskPool::parallel_for_with_worker`]) instead of allocated per
//!   call.
//!
//! Both share the z-contiguous per-tap multiply-add inner loop, which
//! dispatches through [`crate::simd::axpy`] (AVX2+FMA / SSE2 / NEON /
//! scalar); the scalar six-loop oracle lives in
//! [`super::convolve_valid_accumulate_scalar`].

use crate::exec::ExecCtx;
use crate::tensor::Tensor5;
use crate::util::sendptr::SendPtr;

use super::{conv_out_shape, convolve_valid_accumulate_rows, Activation, Weights};

/// Number of x-slabs to split each output image into so the job count
/// `jobs·slabs` covers the pool. One slab (no split) when the `(s, j)`
/// jobs alone saturate the workers — the common large-layer case.
pub(crate) fn slab_count(jobs: usize, extent: usize, workers: usize) -> usize {
    if jobs == 0 || extent == 0 {
        return 1;
    }
    workers.div_ceil(jobs).min(extent)
}

/// Output x-rows `[x0, x1)` of slab `i` of `slabs` over `extent` rows —
/// near-equal split, the first `extent % slabs` slabs one row longer.
pub(crate) fn slab_range(extent: usize, slabs: usize, i: usize) -> (usize, usize) {
    let base = extent / slabs;
    let rem = extent % slabs;
    let x0 = i * base + i.min(rem);
    (x0, x0 + base + usize::from(i < rem))
}

/// Direct convolutional layer, naive inner loop.
pub fn conv_direct_naive(
    input: &Tensor5,
    w: &Weights,
    act: Activation,
    ctx: &mut ExecCtx<'_>,
) -> Tensor5 {
    let pool = ctx.pool();
    let ish = input.shape();
    assert_eq!(ish.f, w.f_in, "channel mismatch");
    let osh = conv_out_shape(ish, w.f_out, w.k);
    let mut out = ctx.tensor5(osh);
    let outp = SendPtr(out.data_mut().as_mut_ptr());
    let plane = osh.y * osh.z;
    // parallel over (s, j, x-slab) — Algorithm 1's two parallel-for
    // loops, plus an x split when S·f' alone can't cover the pool.
    let jobs = ish.s * w.f_out;
    let slabs = slab_count(jobs, osh.x, pool.workers());
    pool.parallel_for(jobs * slabs, |sjx| {
        let (sj, sl) = (sjx / slabs, sjx % slabs);
        let (s, j) = (sj / w.f_out, sj % w.f_out);
        let (x0, x1) = slab_range(osh.x, slabs, sl);
        let o =
            unsafe { outp.slice_mut(osh.image_offset(s, j) + x0 * plane, (x1 - x0) * plane) };
        for i in 0..w.f_in {
            convolve_valid_accumulate_rows(
                input.image(s, i),
                ish.spatial(),
                w.kernel(j, i),
                w.k,
                o,
                x0,
                x1,
            );
        }
        let b = w.bias(j);
        for v in o.iter_mut() {
            *v = act.apply(*v + b);
        }
    });
    out
}

/// Direct convolutional layer, optimised ("MKL") inner loop: per-worker
/// temporary image, z-contiguous fused multiply-add over kernel taps.
pub fn conv_direct_mkl(
    input: &Tensor5,
    w: &Weights,
    act: Activation,
    ctx: &mut ExecCtx<'_>,
) -> Tensor5 {
    let pool = ctx.pool();
    let ish = input.shape();
    assert_eq!(ish.f, w.f_in, "channel mismatch");
    let osh = conv_out_shape(ish, w.f_out, w.k);
    let mut out = ctx.tensor5(osh);
    let outp = SendPtr(out.data_mut().as_mut_ptr());
    let img_len = osh.image_len();
    let plane = osh.y * osh.z;
    let n = ish.spatial();
    // One temporary image per worker (the T·n' of Table II), drawn from
    // the arena so steady-state calls allocate nothing. A worker runs
    // one job at a time, so indexing by worker id is race-free. When
    // jobs are x-slabs each uses only its slab's prefix of the temp.
    let mut tmps: Vec<Vec<f32>> =
        (0..pool.workers()).map(|_| ctx.take_f32_raw(img_len)).collect();
    let tmpp: Vec<SendPtr<f32>> = tmps.iter_mut().map(|b| SendPtr(b.as_mut_ptr())).collect();
    let jobs = ish.s * w.f_out;
    let slabs = slab_count(jobs, osh.x, pool.workers());
    {
        let tmpp = &tmpp;
        pool.parallel_for_with_worker(jobs * slabs, |worker, sjx| {
            let (sj, sl) = (sjx / slabs, sjx % slabs);
            let (s, j) = (sj / w.f_out, sj % w.f_out);
            let (x0, x1) = slab_range(osh.x, slabs, sl);
            let slab_len = (x1 - x0) * plane;
            let o =
                unsafe { outp.slice_mut(osh.image_offset(s, j) + x0 * plane, slab_len) };
            let tmp = unsafe { tmpp[worker].slice_mut(0, slab_len) };
            for i in 0..w.f_in {
                tmp.fill(0.0);
                convolve_valid_accumulate_rows(
                    input.image(s, i),
                    n,
                    w.kernel(j, i),
                    w.k,
                    tmp,
                    x0,
                    x1,
                );
                crate::simd::add_assign(o, tmp);
            }
            let b = w.bias(j);
            for v in o.iter_mut() {
                *v = act.apply(*v + b);
            }
        });
    }
    for t in tmps {
        ctx.put_f32(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv_layer_reference;
    use crate::tensor::Shape5;
    use crate::util::pool::{ChipTopology, TaskPool};
    use crate::util::quick::assert_allclose;

    fn pool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    #[test]
    fn naive_matches_reference() {
        let p = pool();
        let mut ctx = ExecCtx::new(&p);
        let input = Tensor5::random(Shape5::new(2, 3, 6, 7, 8), 1);
        let w = Weights::random(4, 3, [3, 2, 3], 2);
        let expect = conv_layer_reference(&input, &w, Activation::Relu);
        let got = conv_direct_naive(&input, &w, Activation::Relu, &mut ctx);
        assert_allclose(got.data(), expect.data(), 1e-5, 1e-4, "direct naive");
    }

    #[test]
    fn mkl_matches_reference() {
        let p = pool();
        let mut ctx = ExecCtx::new(&p);
        let input = Tensor5::random(Shape5::new(2, 3, 6, 7, 8), 3);
        let w = Weights::random(4, 3, [3, 3, 3], 4);
        let expect = conv_layer_reference(&input, &w, Activation::Relu);
        let got = conv_direct_mkl(&input, &w, Activation::Relu, &mut ctx);
        assert_allclose(got.data(), expect.data(), 1e-5, 1e-4, "direct mkl");
    }

    #[test]
    fn asymmetric_kernels_ok() {
        let p = pool();
        let mut ctx = ExecCtx::new(&p);
        let input = Tensor5::random(Shape5::new(1, 2, 5, 8, 6), 5);
        let w = Weights::random(2, 2, [1, 4, 2], 6);
        let expect = conv_layer_reference(&input, &w, Activation::None);
        for got in [
            conv_direct_naive(&input, &w, Activation::None, &mut ctx),
            conv_direct_mkl(&input, &w, Activation::None, &mut ctx),
        ] {
            assert_allclose(got.data(), expect.data(), 1e-5, 1e-4, "asym");
        }
    }

    #[test]
    fn slab_helpers_cover_and_partition() {
        // Saturated pools never split; starved pools split to coverage.
        assert_eq!(slab_count(8, 10, 4), 1);
        assert_eq!(slab_count(1, 10, 4), 4);
        assert_eq!(slab_count(3, 10, 8), 3); // ceil(8/3) = 3
        assert_eq!(slab_count(1, 2, 16), 2); // capped at the extent
        assert_eq!(slab_count(0, 10, 4), 1);
        assert_eq!(slab_count(4, 0, 4), 1);
        for (extent, slabs) in [(10usize, 4usize), (7, 7), (5, 2), (3, 1)] {
            let mut next = 0;
            for i in 0..slabs {
                let (x0, x1) = slab_range(extent, slabs, i);
                assert_eq!(x0, next, "contiguous at {i}");
                assert!(x1 > x0, "non-empty at {i}");
                next = x1;
            }
            assert_eq!(next, extent, "partition covers {extent}/{slabs}");
        }
    }

    #[test]
    fn single_job_splits_across_workers() {
        // Regression: s·f' = 1 used to run on one worker regardless of
        // pool size. With 4 workers the image must split into x-slabs
        // and still match the reference exactly at the slab seams.
        let p = TaskPool::with_topology(ChipTopology { chips: 2, cores_per_chip: 2 });
        assert_eq!(p.workers(), 4);
        let mut ctx = ExecCtx::new(&p);
        let input = Tensor5::random(Shape5::new(1, 2, 9, 6, 7), 11);
        let w = Weights::random(1, 2, [3, 3, 3], 12);
        let expect = conv_layer_reference(&input, &w, Activation::Relu);
        let naive = conv_direct_naive(&input, &w, Activation::Relu, &mut ctx);
        assert_allclose(naive.data(), expect.data(), 1e-5, 1e-4, "slab naive");
        let mkl = conv_direct_mkl(&input, &w, Activation::Relu, &mut ctx);
        assert_allclose(mkl.data(), expect.data(), 1e-5, 1e-4, "slab mkl");
        // Fewer output rows than workers: the split caps at the extent.
        let input = Tensor5::random(Shape5::new(1, 1, 4, 5, 5), 13);
        let w = Weights::random(1, 1, [3, 3, 3], 14);
        let expect = conv_layer_reference(&input, &w, Activation::None);
        let got = conv_direct_naive(&input, &w, Activation::None, &mut ctx);
        assert_allclose(got.data(), expect.data(), 1e-5, 1e-4, "short slab");
    }

    #[test]
    fn property_direct_variants_agree() {
        let p = pool();
        let mut ctx = ExecCtx::new(&p);
        crate::util::quick::check("direct naive == mkl", |g| {
            let s = g.usize(1, 2);
            let fi = g.usize(1, 3);
            let fo = g.usize(1, 3);
            let k = [g.usize(1, 3), g.usize(1, 3), g.usize(1, 3)];
            let n = [
                k[0] + g.usize(0, 4),
                k[1] + g.usize(0, 4),
                k[2] + g.usize(0, 4),
            ];
            let input = Tensor5::random(Shape5::from_spatial(s, fi, n), g.case as u64);
            let w = Weights::random(fo, fi, k, g.case as u64 + 100);
            let a = conv_direct_naive(&input, &w, Activation::Relu, &mut ctx);
            let b = conv_direct_mkl(&input, &w, Activation::Relu, &mut ctx);
            assert_allclose(b.data(), a.data(), 1e-5, 1e-4, "variants");
        });
    }
}
