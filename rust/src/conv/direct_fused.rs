//! Fused register-tiled direct convolution (PZnet/Budden direction).
//!
//! Two primitives on one tile loop:
//!
//! * [`conv_direct_fused`] — a cache-blocked direct conv that carries a
//!   pair of output-channel accumulator rows across the whole `f_in`
//!   reduction and applies bias+activation in-register before the
//!   single store. Each input row loaded feeds *two* output channels
//!   ([`crate::simd::axpy2`]), halving input bandwidth relative to the
//!   naive/MKL variants, and the output tensor is written exactly once.
//! * [`conv_direct_fused_pool`] — the same loop fused with the *next*
//!   max-pooling layer: each completed window of `p₀` conv x-planes is
//!   pooled immediately ([`crate::pool::pool_one`]), so the
//!   full pre-pool tensor is never materialized. This is the
//!   [`crate::memory::model::conv_pool_fused_memory_bytes`] Table II
//!   row: the `S·f'·n'` inter-layer tensor shrinks to `S·f'·n'/p³`
//!   plus per-worker tiles.
//!
//! Parallelisation follows the direct primitives: `(s, channel-pair,
//! x-slab)` jobs, with the x split sized by the same slab heuristic as
//! [`super::direct`], so small layers still cover the pool.
//!
//! **Bit-identity contract.** Unlike the other vector primitives, which
//! promise tolerance parity, the fused family is *bit-identical* to its
//! scalar oracle ([`conv_fused_reference`]) on every SIMD tier for
//! finite inputs: every tier runs multiply-then-add in the same
//! `(i, a, b, c)` tap order (no FMA anywhere — see
//! [`crate::simd::axpy2`]), zero-valued taps are *not* skipped, and the
//! ReLU is the same `max(v, 0)` on every path. The property suite
//! asserts exact equality across all forced tiers.

use crate::exec::ExecCtx;
use crate::pool::{max_pool_out_shape, pool_one, pool_one_scalar};
use crate::tensor::{Tensor5, Vec3};
use crate::util::sendptr::SendPtr;

use super::direct::{slab_count, slab_range};
use super::{conv_out_shape, Activation, Weights};

/// Accumulate every tap of input row `(x+a, y+b)` into the channel-pair
/// accumulator rows. Factored out so the plain and pooled variants run
/// the identical instruction sequence.
#[inline]
#[allow(clippy::too_many_arguments)]
fn accumulate_pair(
    tier: crate::simd::Tier,
    acc0: &mut [f32],
    acc1: &mut [f32],
    img: &[f32],
    n: Vec3,
    ker0: &[f32],
    ker1: &[f32],
    k: Vec3,
    x: usize,
    y: usize,
) {
    let on2 = acc0.len();
    for a in 0..k[0] {
        for b in 0..k[1] {
            let irow = ((x + a) * n[1] + (y + b)) * n[2];
            for c in 0..k[2] {
                let ki = ((k[0] - 1 - a) * k[1] + (k[1] - 1 - b)) * k[2] + (k[2] - 1 - c);
                // No zero-tap skip: the oracle adds every product, and
                // skipping would perturb signed-zero accumulation.
                crate::simd::axpy2_tier(
                    tier,
                    acc0,
                    acc1,
                    &img[irow + c..irow + c + on2],
                    ker0[ki],
                    ker1[ki],
                );
            }
        }
    }
}

/// Register-tiled direct convolutional layer with fused bias+activation.
///
/// Output and semantics match [`super::conv_layer_reference`] up to
/// summation order; bit-for-bit it matches [`conv_fused_reference`] on
/// every SIMD tier (see the module doc for the contract).
pub fn conv_direct_fused(
    input: &Tensor5,
    w: &Weights,
    act: Activation,
    ctx: &mut ExecCtx<'_>,
) -> Tensor5 {
    let pool = ctx.pool();
    let ish = input.shape();
    assert_eq!(ish.f, w.f_in, "channel mismatch");
    let osh = conv_out_shape(ish, w.f_out, w.k);
    let n = ish.spatial();
    let on = osh.spatial();
    let relu = act == Activation::Relu;
    let mut out = ctx.tensor5(osh);
    let outp = SendPtr(out.data_mut().as_mut_ptr());
    // Two accumulator rows per worker — the whole per-thread working
    // set beyond the tensors themselves (the `T·2·n'_z` of Table II).
    let mut tiles: Vec<Vec<f32>> =
        (0..pool.workers()).map(|_| ctx.take_f32_raw(2 * on[2])).collect();
    let tilep: Vec<SendPtr<f32>> = tiles.iter_mut().map(|b| SendPtr(b.as_mut_ptr())).collect();
    let jpairs = w.f_out.div_ceil(2);
    let jobs = ish.s * jpairs;
    let slabs = slab_count(jobs, on[0], pool.workers());
    let tier = crate::simd::active();
    {
        let tilep = &tilep;
        pool.parallel_for_with_worker(jobs * slabs, |worker, sjx| {
            let (sj, sl) = (sjx / slabs, sjx % slabs);
            let (s, jp) = (sj / jpairs, sj % jpairs);
            let j0 = 2 * jp;
            let j1 = (j0 + 1).min(w.f_out - 1); // odd f_out: j1 == j0
            let (x0, x1) = slab_range(on[0], slabs, sl);
            let buf = unsafe { tilep[worker].slice_mut(0, 2 * on[2]) };
            let (acc0, acc1) = buf.split_at_mut(on[2]);
            for x in x0..x1 {
                for y in 0..on[1] {
                    acc0.fill(0.0);
                    acc1.fill(0.0);
                    for i in 0..w.f_in {
                        accumulate_pair(
                            tier,
                            acc0,
                            acc1,
                            input.image(s, i),
                            n,
                            w.kernel(j0, i),
                            w.kernel(j1, i),
                            w.k,
                            x,
                            y,
                        );
                    }
                    let ob = osh.image_offset(s, j0) + (x * on[1] + y) * on[2];
                    let orow = unsafe { outp.slice_mut(ob, on[2]) };
                    crate::simd::store_bias_act_tier(tier, orow, acc0, w.bias(j0), relu);
                    if j1 != j0 {
                        let ob = osh.image_offset(s, j1) + (x * on[1] + y) * on[2];
                        let orow = unsafe { outp.slice_mut(ob, on[2]) };
                        crate::simd::store_bias_act_tier(tier, orow, acc1, w.bias(j1), relu);
                    }
                }
            }
        });
    }
    for t in tiles {
        ctx.put_f32(t);
    }
    out
}

/// [`conv_direct_fused`] with the following max-pool fused in: returns
/// the *pooled* output directly, never materializing the pre-pool
/// tensor. The conv output extents must be divisible by `p` (the same
/// precondition as [`max_pool_out_shape`]).
///
/// Each worker computes `p₀` conv x-planes of a channel pair into a
/// tile (bias+activation applied on store), pools the tile into one
/// output plane per channel, and moves on — so the transient footprint
/// is `T` tiles of `2·(p₀·n'_y·n'_z + n'_z)` floats.
pub fn conv_direct_fused_pool(
    input: &Tensor5,
    w: &Weights,
    act: Activation,
    p: Vec3,
    ctx: &mut ExecCtx<'_>,
) -> Tensor5 {
    let pool = ctx.pool();
    let ish = input.shape();
    assert_eq!(ish.f, w.f_in, "channel mismatch");
    let csh = conv_out_shape(ish, w.f_out, w.k);
    let osh = max_pool_out_shape(csh, p);
    let n = ish.spatial();
    let on = csh.spatial();
    let po = osh.spatial();
    let relu = act == Activation::Relu;
    let mut out = ctx.tensor5(osh);
    let outp = SendPtr(out.data_mut().as_mut_ptr());
    // Per-worker scratch: a pair of accumulator rows plus a pair of
    // p₀-plane channel tiles (the `T·2·(p₀·n'_y·n'_z + n'_z)` of the
    // fused Table II row).
    let plane = on[1] * on[2];
    let tile_len = p[0] * plane;
    let scratch = 2 * on[2] + 2 * tile_len;
    let mut tiles: Vec<Vec<f32>> =
        (0..pool.workers()).map(|_| ctx.take_f32_raw(scratch)).collect();
    let tilep: Vec<SendPtr<f32>> = tiles.iter_mut().map(|b| SendPtr(b.as_mut_ptr())).collect();
    let jpairs = w.f_out.div_ceil(2);
    let jobs = ish.s * jpairs;
    let slabs = slab_count(jobs, po[0], pool.workers());
    let tier = crate::simd::active();
    {
        let tilep = &tilep;
        pool.parallel_for_with_worker(jobs * slabs, |worker, sjx| {
            let (sj, sl) = (sjx / slabs, sjx % slabs);
            let (s, jp) = (sj / jpairs, sj % jpairs);
            let j0 = 2 * jp;
            let j1 = (j0 + 1).min(w.f_out - 1);
            let (px0, px1) = slab_range(po[0], slabs, sl);
            let buf = unsafe { tilep[worker].slice_mut(0, scratch) };
            let (accs, tbuf) = buf.split_at_mut(2 * on[2]);
            let (acc0, acc1) = accs.split_at_mut(on[2]);
            let (tile0, tile1) = tbuf.split_at_mut(tile_len);
            for px in px0..px1 {
                for dx in 0..p[0] {
                    let x = px * p[0] + dx;
                    for y in 0..on[1] {
                        acc0.fill(0.0);
                        acc1.fill(0.0);
                        for i in 0..w.f_in {
                            accumulate_pair(
                                tier,
                                acc0,
                                acc1,
                                input.image(s, i),
                                n,
                                w.kernel(j0, i),
                                w.kernel(j1, i),
                                w.k,
                                x,
                                y,
                            );
                        }
                        let tb = (dx * on[1] + y) * on[2];
                        crate::simd::store_bias_act_tier(
                            tier,
                            &mut tile0[tb..tb + on[2]],
                            acc0,
                            w.bias(j0),
                            relu,
                        );
                        if j1 != j0 {
                            crate::simd::store_bias_act_tier(
                                tier,
                                &mut tile1[tb..tb + on[2]],
                                acc1,
                                w.bias(j1),
                                relu,
                            );
                        }
                    }
                }
                // The tile holds conv planes [px·p₀, px·p₀+p₀) with
                // bias+activation applied — pool it straight into the
                // output plane and reuse the tile for the next window.
                let ob = osh.image_offset(s, j0) + px * po[1] * po[2];
                let oplane = unsafe { outp.slice_mut(ob, po[1] * po[2]) };
                pool_one(tile0, [p[0], on[1], on[2]], p, [0, 0, 0], [1, po[1], po[2]], oplane);
                if j1 != j0 {
                    let ob = osh.image_offset(s, j1) + px * po[1] * po[2];
                    let oplane = unsafe { outp.slice_mut(ob, po[1] * po[2]) };
                    pool_one(tile1, [p[0], on[1], on[2]], p, [0, 0, 0], [1, po[1], po[2]], oplane);
                }
            }
        });
    }
    for t in tiles {
        ctx.put_f32(t);
    }
    out
}

/// Scalar oracle of the fused family: one accumulator per output
/// element, summed over *all* taps of *all* input channels in
/// `(i, a, b, c)` order, then `act(acc + bias)` — exactly the operation
/// sequence every [`conv_direct_fused`] tier runs per element. Note
/// this differs from [`super::conv_layer_reference`], which accumulates
/// per-channel partial images (different rounding).
pub fn conv_fused_reference(input: &Tensor5, w: &Weights, act: Activation) -> Tensor5 {
    let ish = input.shape();
    assert_eq!(ish.f, w.f_in, "channel mismatch");
    let osh = conv_out_shape(ish, w.f_out, w.k);
    let n = ish.spatial();
    let on = osh.spatial();
    let k = w.k;
    let mut out = Tensor5::zeros(osh);
    for s in 0..ish.s {
        for j in 0..w.f_out {
            let bias = w.bias(j);
            let o = out.image_mut(s, j);
            for x in 0..on[0] {
                for y in 0..on[1] {
                    for z in 0..on[2] {
                        let mut acc = 0.0f32;
                        for i in 0..w.f_in {
                            let img = input.image(s, i);
                            let ker = w.kernel(j, i);
                            for a in 0..k[0] {
                                for b in 0..k[1] {
                                    for c in 0..k[2] {
                                        let iv = img[((x + a) * n[1] + (y + b)) * n[2] + (z + c)];
                                        let kv = ker[((k[0] - 1 - a) * k[1] + (k[1] - 1 - b))
                                            * k[2]
                                            + (k[2] - 1 - c)];
                                        acc += iv * kv;
                                    }
                                }
                            }
                        }
                        o[(x * on[1] + y) * on[2] + z] = act.apply(acc + bias);
                    }
                }
            }
        }
    }
    out
}

/// Scalar oracle of [`conv_direct_fused_pool`]: the fused reference
/// followed by the scalar pooling sweep, per image.
pub fn conv_fused_pool_reference(
    input: &Tensor5,
    w: &Weights,
    act: Activation,
    p: Vec3,
) -> Tensor5 {
    let conv = conv_fused_reference(input, w, act);
    let csh = conv.shape();
    let osh = max_pool_out_shape(csh, p);
    let mut out = Tensor5::zeros(osh);
    for s in 0..csh.s {
        for j in 0..csh.f {
            pool_one_scalar(
                conv.image(s, j),
                csh.spatial(),
                p,
                [0, 0, 0],
                osh.spatial(),
                out.image_mut(s, j),
            );
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::conv_layer_reference;
    use crate::pool::max_pool;
    use crate::tensor::Shape5;
    use crate::util::pool::{ChipTopology, TaskPool};
    use crate::util::quick::assert_allclose;

    fn pool() -> TaskPool {
        TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
    }

    #[test]
    fn fused_matches_layer_reference_within_tolerance() {
        // Different summation order than the per-channel reference →
        // tolerance parity here; bit-identity is against its own oracle.
        let p = pool();
        let mut ctx = ExecCtx::new(&p);
        let input = Tensor5::random(Shape5::new(2, 3, 6, 7, 8), 21);
        let w = Weights::random(4, 3, [3, 2, 3], 22);
        let expect = conv_layer_reference(&input, &w, Activation::Relu);
        let got = conv_direct_fused(&input, &w, Activation::Relu, &mut ctx);
        assert_allclose(got.data(), expect.data(), 1e-4, 1e-3, "fused vs layer ref");
    }

    #[test]
    fn fused_is_bit_identical_to_its_oracle() {
        // Odd f_out (register-tile tail) and odd extents on purpose.
        let p = pool();
        let mut ctx = ExecCtx::new(&p);
        for (s, fi, fo, k) in [(1, 1, 1, [1, 1, 1]), (2, 3, 5, [3, 2, 3]), (1, 2, 4, [2, 2, 2])] {
            let n = [k[0] + 4, k[1] + 6, k[2] + 5];
            let input = Tensor5::random(Shape5::from_spatial(s, fi, n), 31);
            let w = Weights::random(fo, fi, k, 32);
            for act in [Activation::None, Activation::Relu] {
                let expect = conv_fused_reference(&input, &w, act);
                let got = conv_direct_fused(&input, &w, act, &mut ctx);
                assert_allclose(got.data(), expect.data(), 0.0, 0.0, "fused oracle");
            }
        }
    }

    #[test]
    fn fused_pool_is_bit_identical_to_its_oracle() {
        let p = pool();
        let mut ctx = ExecCtx::new(&p);
        for (fo, pw) in [(4usize, [2, 2, 2]), (3, [1, 2, 2]), (5, [2, 1, 3])] {
            // Input sized so the conv output divides the pool window.
            let k = [3, 3, 3];
            let n = [k[0] - 1 + pw[0] * 3, k[1] - 1 + pw[1] * 2, k[2] - 1 + pw[2] * 2];
            let input = Tensor5::random(Shape5::from_spatial(1, 2, n), 41);
            let w = Weights::random(fo, 2, k, 42);
            let expect = conv_fused_pool_reference(&input, &w, Activation::Relu, pw);
            let got = conv_direct_fused_pool(&input, &w, Activation::Relu, pw, &mut ctx);
            assert_allclose(got.data(), expect.data(), 0.0, 0.0, "fused-pool oracle");
        }
    }

    #[test]
    fn fused_pool_matches_separate_conv_then_pool() {
        // The fusion must be invisible: same result as running the
        // fused conv and the standalone max-pool primitive in sequence.
        let p = pool();
        let mut ctx = ExecCtx::new(&p);
        let pw = [2, 2, 2];
        let input = Tensor5::random(Shape5::new(2, 2, 6, 8, 8), 51);
        let w = Weights::random(3, 2, [3, 3, 3], 52);
        let conv = conv_direct_fused(&input, &w, Activation::Relu, &mut ctx);
        let expect = max_pool(&conv, pw, &mut ctx);
        let got = conv_direct_fused_pool(&input, &w, Activation::Relu, pw, &mut ctx);
        assert_allclose(got.data(), expect.data(), 0.0, 0.0, "fused vs separate");
    }

    #[test]
    fn property_fused_agrees_with_oracle() {
        let p = pool();
        let mut ctx = ExecCtx::new(&p);
        crate::util::quick::check("fused == oracle", |g| {
            let s = g.usize(1, 2);
            let fi = g.usize(1, 3);
            let fo = g.usize(1, 5);
            let k = [g.usize(1, 3), g.usize(1, 3), g.usize(1, 3)];
            let n = [k[0] + g.usize(0, 4), k[1] + g.usize(0, 4), k[2] + g.usize(0, 4)];
            let input = Tensor5::random(Shape5::from_spatial(s, fi, n), g.case as u64);
            let w = Weights::random(fo, fi, k, g.case as u64 + 100);
            let expect = conv_fused_reference(&input, &w, Activation::Relu);
            let got = conv_direct_fused(&input, &w, Activation::Relu, &mut ctx);
            assert_allclose(got.data(), expect.data(), 0.0, 0.0, "prop fused");
        });
    }
}
