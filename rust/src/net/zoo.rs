//! The four benchmark architectures of Table III.
//!
//! * **n337** — CPCPCPCCCC, 7 conv (first 2³, rest 3³) + 3 pool 2³;
//! * **n537** — CPCPCPCCCC, 7 conv (first 4³, rest 5³) + 3 pool 2³;
//! * **n726** — CPCPCCCC, 6 conv (first 6³, rest 7³) + 2 pool 2³;
//! * **n926** — CPCPCCCC, 6 conv (first 8³, rest 9³) + 2 pool 2³.
//!
//! All hidden layers have 80 feature maps, the output layer 3 (the
//! paper's affinity-graph outputs). The paper's sizes need hundreds of
//! GB and many core-hours per data point, so the zoo also provides
//! scaled variants with fewer maps — same topology, same constraint
//! structure — selected by [`NetScale`].

use super::spec::{LayerSpec, NetSpec};
use crate::tensor::Vec3;

/// Feature-map scale for the zoo.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetScale {
    /// Paper scale: 80 maps.
    Paper,
    /// Small: 8 maps — minutes-scale benches on this testbed.
    Small,
    /// Tiny: 4 maps — CI smoke.
    Tiny,
}

impl NetScale {
    /// Feature maps per hidden layer at this scale.
    pub fn fmaps(&self) -> usize {
        match self {
            NetScale::Paper => 80,
            NetScale::Small => 8,
            NetScale::Tiny => 4,
        }
    }

    /// Read the scale from `ZNNI_SCALE` (paper|small|tiny; default small).
    pub fn from_env() -> Self {
        match std::env::var("ZNNI_SCALE").as_deref() {
            Ok("paper") => NetScale::Paper,
            Ok("tiny") => NetScale::Tiny,
            _ => NetScale::Small,
        }
    }
}

fn c(f_out: usize, k: usize) -> LayerSpec {
    LayerSpec::Conv { f_out, k: [k, k, k] }
}

fn p(w: usize) -> LayerSpec {
    LayerSpec::Pool { p: [w, w, w] }
}

/// CPCPCPCCCC with first kernel `k1` and body kernel `k`.
fn deep10(name: &str, fm: usize, k1: usize, k: usize) -> NetSpec {
    NetSpec {
        name: name.into(),
        f_in: 1,
        layers: vec![
            c(fm, k1),
            p(2),
            c(fm, k),
            p(2),
            c(fm, k),
            p(2),
            c(fm, k),
            c(fm, k),
            c(fm, k),
            c(3, k),
        ],
    }
}

/// CPCPCCCC with first kernel `k1` and body kernel `k`.
fn deep8(name: &str, fm: usize, k1: usize, k: usize) -> NetSpec {
    NetSpec {
        name: name.into(),
        f_in: 1,
        layers: vec![c(fm, k1), p(2), c(fm, k), p(2), c(fm, k), c(fm, k), c(fm, k), c(3, k)],
    }
}

/// n337 (Table III column 1).
pub fn n337(scale: NetScale) -> NetSpec {
    deep10("n337", scale.fmaps(), 2, 3)
}

/// n537 (Table III column 2).
pub fn n537(scale: NetScale) -> NetSpec {
    deep10("n537", scale.fmaps(), 4, 5)
}

/// n726 (Table III column 3).
pub fn n726(scale: NetScale) -> NetSpec {
    deep8("n726", scale.fmaps(), 6, 7)
}

/// n926 (Table III column 4).
pub fn n926(scale: NetScale) -> NetSpec {
    deep8("n926", scale.fmaps(), 8, 9)
}

/// All four benchmark nets.
pub fn benchmark_nets(scale: NetScale) -> Vec<NetSpec> {
    vec![n337(scale), n537(scale), n726(scale), n926(scale)]
}

/// Look up a benchmark net by name.
pub fn net_by_name(name: &str, scale: NetScale) -> Option<NetSpec> {
    match name {
        "n337" => Some(n337(scale)),
        "n537" => Some(n537(scale)),
        "n726" => Some(n726(scale)),
        "n926" => Some(n926(scale)),
        _ => None,
    }
}

/// A 4-layer net for tests and the quickstart example: CPCC with 3³
/// kernels.
pub fn tiny_net(fm: usize) -> NetSpec {
    NetSpec {
        name: "tiny-cpcc".into(),
        f_in: 1,
        layers: vec![c(fm, 3), p(2), c(fm, 3), c(2, 3)],
    }
}

/// Topology-preserving miniatures of the four Table III nets for the
/// measured benches on this single-core testbed: same C/P pattern and
/// pooling counts, kernels shrunk so the FoV is ~10–20 voxels and a
/// patch runs in well under a second. The paper-shape claims these
/// benches check (who wins, where crossovers fall, MPF ≫ naive) are
/// topology-structural and survive the shrink; `ZNNI_SCALE=paper`
/// switches the benches to the true Table III nets.
pub fn bench_miniatures() -> Vec<NetSpec> {
    let m = |name: &str, layers: Vec<LayerSpec>| NetSpec { name: name.into(), f_in: 1, layers };
    vec![
        // 2 pools + small kernels ~ n337's CPCPC... family
        m("mini337", vec![c(6, 2), p(2), c(6, 2), p(2), c(3, 3)]),
        // larger kernels, 2 pools ~ n537
        m("mini537", vec![c(6, 3), p(2), c(6, 3), p(2), c(3, 3)]),
        // 1 pool, larger kernels ~ n726
        m("mini726", vec![c(6, 3), p(2), c(6, 4), c(3, 4)]),
        // 1 pool, largest kernels ~ n926
        m("mini926", vec![c(6, 4), p(2), c(6, 5), c(3, 5)]),
    ]
}

/// Pooling window of pool layer `i` (helper for mode vectors).
pub fn pool_windows(net: &NetSpec) -> Vec<Vec3> {
    net.layers
        .iter()
        .filter_map(|l| match l {
            LayerSpec::Pool { p } => Some(*p),
            _ => None,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::spec::PoolingMode;

    #[test]
    fn table3_layer_counts() {
        let s = NetScale::Paper;
        assert_eq!(n337(s).conv_count(), 7);
        assert_eq!(n337(s).pool_count(), 3);
        assert_eq!(n537(s).conv_count(), 7);
        assert_eq!(n537(s).pool_count(), 3);
        assert_eq!(n726(s).conv_count(), 6);
        assert_eq!(n726(s).pool_count(), 2);
        assert_eq!(n926(s).conv_count(), 6);
        assert_eq!(n926(s).pool_count(), 2);
    }

    #[test]
    fn paper_scale_has_80_maps() {
        let net = n537(NetScale::Paper);
        assert!(matches!(net.layers[0], LayerSpec::Conv { f_out: 80, .. }));
        assert_eq!(net.f_out(), 3);
    }

    #[test]
    fn fields_of_view_are_large() {
        // The paper chose these nets for fairly large FoV.
        for (net, expect) in [
            (n337(NetScale::Paper), [85, 85, 85]),
            (n537(NetScale::Paper), [163, 163, 163]),
            (n726(NetScale::Paper), [117, 117, 117]),
            (n926(NetScale::Paper), [155, 155, 155]),
        ] {
            assert_eq!(net.field_of_view(), expect, "{}", net.name);
        }
    }

    #[test]
    fn all_nets_accept_some_mpf_input() {
        for net in benchmark_nets(NetScale::Tiny) {
            let modes = vec![PoolingMode::Mpf; net.pool_count()];
            let m = net.min_extent(&modes);
            assert!(m.is_some(), "{} has no valid MPF input", net.name);
        }
    }

    #[test]
    fn zoo_lookup() {
        assert!(net_by_name("n337", NetScale::Tiny).is_some());
        assert!(net_by_name("n999", NetScale::Tiny).is_none());
    }

    #[test]
    fn roundtrip_through_config_format() {
        for net in benchmark_nets(NetScale::Paper) {
            let parsed = NetSpec::parse(&net.to_text()).unwrap();
            assert_eq!(parsed.layers, net.layers);
        }
    }
}
