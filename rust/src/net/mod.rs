//! Network specification, shape propagation and the benchmark zoo.
//!
//! [`spec`] defines the architecture description (a tiny config format
//! plus programmatic builders), shape propagation per Table I including
//! the MPF batch-size multiplication, field-of-view math, and valid
//! input-size enumeration. [`zoo`] provides the four benchmarked
//! architectures of Table III (n337, n537, n726, n926) at the paper's
//! scale and at reduced test scales.

pub mod spec;
pub mod zoo;

pub use spec::{LayerSpec, NetSpec, PoolingMode};
pub use zoo::{benchmark_nets, net_by_name, NetScale};
