//! Architecture specs and shape propagation.

use anyhow::{anyhow, bail, Result};

use crate::tensor::{Shape5, Vec3};

/// One layer of an architecture (Table III rows).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LayerSpec {
    /// Convolution (+ ReLU).
    Conv {
        /// Output maps (f').
        f_out: usize,
        /// Kernel extent per dimension.
        k: Vec3,
    },
    /// Pooling — executed as max-pool or MPF depending on the chosen
    /// [`PoolingMode`].
    Pool {
        /// Pooling window per dimension.
        p: Vec3,
    },
}

/// How a pooling layer is realised (§V–VI: every max-pooling layer may
/// be replaced by an MPF layer).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolingMode {
    /// Plain max-pooling (stride = window).
    MaxPool,
    /// Max-pooling fragments: all p^3 offsets, multiplying the batch (§V).
    Mpf,
}

/// A network architecture: input maps + layer list.
#[derive(Clone, Debug)]
pub struct NetSpec {
    /// Display name (Tables I/III).
    pub name: String,
    /// Input images of the first layer.
    pub f_in: usize,
    /// Layer list, input to output.
    pub layers: Vec<LayerSpec>,
}

impl NetSpec {
    /// Number of pooling layers (length of a pooling-mode assignment).
    pub fn pool_count(&self) -> usize {
        self.layers.iter().filter(|l| matches!(l, LayerSpec::Pool { .. })).count()
    }

    /// Number of conv layers.
    pub fn conv_count(&self) -> usize {
        self.layers.len() - self.pool_count()
    }

    /// Output maps of the final conv layer.
    pub fn f_out(&self) -> usize {
        self.layers
            .iter()
            .rev()
            .find_map(|l| match l {
                LayerSpec::Conv { f_out, .. } => Some(*f_out),
                _ => None,
            })
            .unwrap_or(self.f_in)
    }

    /// Propagate shapes through the net for a given input shape and
    /// per-pool-layer mode assignment. Returns the shape *after* each
    /// layer (`result[i]` = output of layer i), or an error naming the
    /// first layer whose constraint fails.
    pub fn shapes(&self, input: Shape5, modes: &[PoolingMode]) -> Result<Vec<Shape5>> {
        assert_eq!(modes.len(), self.pool_count(), "one mode per pooling layer");
        let mut cur = input;
        let mut pool_i = 0;
        let mut out = Vec::with_capacity(self.layers.len());
        for (li, l) in self.layers.iter().enumerate() {
            cur = match l {
                LayerSpec::Conv { f_out, k } => {
                    if cur.f
                        != self.f_in_at(li)
                    {
                        bail!("layer {li}: channel mismatch");
                    }
                    if cur.x < k[0] || cur.y < k[1] || cur.z < k[2] {
                        bail!("layer {li}: image {cur} smaller than kernel {k:?}");
                    }
                    Shape5 {
                        s: cur.s,
                        f: *f_out,
                        x: cur.x - k[0] + 1,
                        y: cur.y - k[1] + 1,
                        z: cur.z - k[2] + 1,
                    }
                }
                LayerSpec::Pool { p } => {
                    let mode = modes[pool_i];
                    pool_i += 1;
                    match mode {
                        PoolingMode::MaxPool => {
                            if cur.x % p[0] != 0 || cur.y % p[1] != 0 || cur.z % p[2] != 0 {
                                bail!("layer {li}: {cur} not divisible by pool {p:?}");
                            }
                            Shape5 {
                                x: cur.x / p[0],
                                y: cur.y / p[1],
                                z: cur.z / p[2],
                                ..cur
                            }
                        }
                        PoolingMode::Mpf => {
                            if (cur.x + 1) % p[0] != 0
                                || (cur.y + 1) % p[1] != 0
                                || (cur.z + 1) % p[2] != 0
                            {
                                bail!("layer {li}: {cur}+1 not divisible by MPF {p:?}");
                            }
                            Shape5 {
                                s: cur.s * p[0] * p[1] * p[2],
                                f: cur.f,
                                x: cur.x / p[0],
                                y: cur.y / p[1],
                                z: cur.z / p[2],
                            }
                        }
                    }
                }
            };
            out.push(cur);
        }
        Ok(out)
    }

    /// Input maps expected by layer `li`.
    pub fn f_in_at(&self, li: usize) -> usize {
        self.layers[..li]
            .iter()
            .rev()
            .find_map(|l| match l {
                LayerSpec::Conv { f_out, .. } => Some(*f_out),
                _ => None,
            })
            .unwrap_or(self.f_in)
    }

    /// Whether a cubic input of extent `n` (batch `s`) is valid for the
    /// given pooling-mode assignment and yields non-empty output.
    pub fn accepts_extent(&self, n: usize, s: usize, modes: &[PoolingMode]) -> bool {
        self.shapes(Shape5::new(s, self.f_in, n, n, n), modes).is_ok()
    }

    /// All valid cubic input extents in `[lo, hi]` for the given modes.
    pub fn valid_extents(&self, lo: usize, hi: usize, modes: &[PoolingMode]) -> Vec<usize> {
        (lo..=hi).filter(|&n| self.accepts_extent(n, 1, modes)).collect()
    }

    /// Smallest valid cubic input extent (searches up to 4096).
    pub fn min_extent(&self, modes: &[PoolingMode]) -> Option<usize> {
        (1..=4096).find(|&n| self.accepts_extent(n, 1, modes))
    }

    /// Field of view of the sliding window: the input extent for which
    /// the dense ConvNet yields exactly one output voxel. Computed per
    /// dimension with the standard fov/stride recursion.
    pub fn field_of_view(&self) -> Vec3 {
        let mut fov = [1isize; 3];
        let mut jump = [1isize; 3];
        for l in &self.layers {
            match l {
                LayerSpec::Conv { k, .. } => {
                    for d in 0..3 {
                        fov[d] += (k[d] as isize - 1) * jump[d];
                    }
                }
                LayerSpec::Pool { p } => {
                    for d in 0..3 {
                        fov[d] += (p[d] as isize - 1) * jump[d];
                        jump[d] *= p[d] as isize;
                    }
                }
            }
        }
        [fov[0] as usize, fov[1] as usize, fov[2] as usize]
    }

    /// Product of MPF fragment counts (α in §VI.A): how many fragments
    /// one input produces when all `modes[i] == Mpf`.
    pub fn fragment_factor(&self, modes: &[PoolingMode]) -> usize {
        let mut a = 1;
        let mut pool_i = 0;
        for l in &self.layers {
            if let LayerSpec::Pool { p } = l {
                if modes[pool_i] == PoolingMode::Mpf {
                    a *= p[0] * p[1] * p[2];
                }
                pool_i += 1;
            }
        }
        a
    }

    /// Total stride of the sliding window (per dimension) — the product
    /// of pooling windows; MPF recombination interleaves at this stride.
    pub fn total_stride(&self) -> Vec3 {
        let mut s = [1usize; 3];
        for l in &self.layers {
            if let LayerSpec::Pool { p } = l {
                for d in 0..3 {
                    s[d] *= p[d];
                }
            }
        }
        s
    }

    /// Parse the tiny config format:
    ///
    /// ```text
    /// name n337
    /// input 1
    /// conv 80 2          # f_out, cubic kernel
    /// pool 2             # cubic window
    /// conv 80 3 3 3      # f_out, kx ky kz
    /// ```
    pub fn parse(text: &str) -> Result<NetSpec> {
        let mut name = String::from("unnamed");
        let mut f_in = None;
        let mut layers = Vec::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            let parse_dims = |nums: &[&str]| -> Result<Vec3> {
                let v: Vec<usize> =
                    nums.iter().map(|t| t.parse()).collect::<std::result::Result<_, _>>()?;
                Ok(match v.len() {
                    1 => [v[0], v[0], v[0]],
                    3 => [v[0], v[1], v[2]],
                    _ => bail!("line {}: expected 1 or 3 extents", ln + 1),
                })
            };
            match toks[0] {
                "name" => {
                    name = toks.get(1).ok_or_else(|| anyhow!("line {}: name?", ln + 1))?.to_string()
                }
                "input" => {
                    f_in = Some(
                        toks.get(1)
                            .ok_or_else(|| anyhow!("line {}: input maps?", ln + 1))?
                            .parse()?,
                    )
                }
                "conv" => {
                    if toks.len() < 3 {
                        bail!("line {}: conv F K", ln + 1);
                    }
                    layers.push(LayerSpec::Conv {
                        f_out: toks[1].parse()?,
                        k: parse_dims(&toks[2..])?,
                    });
                }
                "pool" => {
                    if toks.len() < 2 {
                        bail!("line {}: pool P", ln + 1);
                    }
                    layers.push(LayerSpec::Pool { p: parse_dims(&toks[1..])? });
                }
                other => bail!("line {}: unknown directive '{other}'", ln + 1),
            }
        }
        let f_in = f_in.ok_or_else(|| anyhow!("missing 'input' directive"))?;
        if layers.is_empty() {
            bail!("no layers");
        }
        Ok(NetSpec { name, f_in, layers })
    }

    /// Serialise back to the config format.
    pub fn to_text(&self) -> String {
        let mut s = format!("name {}\ninput {}\n", self.name, self.f_in);
        for l in &self.layers {
            match l {
                LayerSpec::Conv { f_out, k } => {
                    s.push_str(&format!("conv {} {} {} {}\n", f_out, k[0], k[1], k[2]))
                }
                LayerSpec::Pool { p } => {
                    s.push_str(&format!("pool {} {} {}\n", p[0], p[1], p[2]))
                }
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> NetSpec {
        NetSpec {
            name: "tiny".into(),
            f_in: 1,
            layers: vec![
                LayerSpec::Conv { f_out: 2, k: [3, 3, 3] },
                LayerSpec::Pool { p: [2, 2, 2] },
                LayerSpec::Conv { f_out: 1, k: [3, 3, 3] },
            ],
        }
    }

    #[test]
    fn shape_propagation_maxpool() {
        let net = tiny();
        let shapes = net
            .shapes(Shape5::new(1, 1, 10, 10, 10), &[PoolingMode::MaxPool])
            .unwrap();
        assert_eq!(shapes[0], Shape5::new(1, 2, 8, 8, 8));
        assert_eq!(shapes[1], Shape5::new(1, 2, 4, 4, 4));
        assert_eq!(shapes[2], Shape5::new(1, 1, 2, 2, 2));
    }

    #[test]
    fn shape_propagation_mpf_multiplies_batch() {
        let net = tiny();
        let shapes = net.shapes(Shape5::new(1, 1, 11, 11, 11), &[PoolingMode::Mpf]).unwrap();
        assert_eq!(shapes[0], Shape5::new(1, 2, 9, 9, 9));
        assert_eq!(shapes[1], Shape5::new(8, 2, 4, 4, 4));
        assert_eq!(shapes[2], Shape5::new(8, 1, 2, 2, 2));
    }

    #[test]
    fn invalid_sizes_error() {
        let net = tiny();
        assert!(net.shapes(Shape5::new(1, 1, 9, 9, 9), &[PoolingMode::MaxPool]).is_err());
        assert!(net.shapes(Shape5::new(1, 1, 10, 10, 10), &[PoolingMode::Mpf]).is_err());
        assert!(net.shapes(Shape5::new(1, 1, 4, 4, 4), &[PoolingMode::MaxPool]).is_err());
    }

    #[test]
    fn field_of_view_recursion() {
        let net = tiny();
        // conv3: fov 3; pool2: fov 4, jump 2; conv3: fov 4 + 2*2 = 8.
        assert_eq!(net.field_of_view(), [8, 8, 8]);
        // FoV input must produce output extent 1 in dense mode... the
        // smallest valid MaxPool input is the FoV here.
        assert_eq!(net.min_extent(&[PoolingMode::MaxPool]), Some(8));
    }

    #[test]
    fn fragment_factor_and_stride() {
        let net = tiny();
        assert_eq!(net.fragment_factor(&[PoolingMode::Mpf]), 8);
        assert_eq!(net.fragment_factor(&[PoolingMode::MaxPool]), 1);
        assert_eq!(net.total_stride(), [2, 2, 2]);
    }

    #[test]
    fn valid_extents_mpf() {
        let net = tiny();
        let v = net.valid_extents(1, 30, &[PoolingMode::Mpf]);
        // Need (n-2)+1 ≡ 0 mod 2 → n odd; and fragments ≥ kernel.
        assert!(v.iter().all(|n| n % 2 == 1));
        assert!(v.contains(&11));
        assert!(!v.contains(&7)); // fragment (7-2)/2=2 < kernel 3
    }

    #[test]
    fn parse_roundtrip() {
        let text = "name t\ninput 1\nconv 4 3\npool 2\nconv 2 3 1 2\n";
        let net = NetSpec::parse(text).unwrap();
        assert_eq!(net.layers.len(), 3);
        assert_eq!(net.layers[2], LayerSpec::Conv { f_out: 2, k: [3, 1, 2] });
        let net2 = NetSpec::parse(&net.to_text()).unwrap();
        assert_eq!(net.layers, net2.layers);
        assert_eq!(net.f_in, net2.f_in);
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(NetSpec::parse("input 1\nfrobnicate 3\n").is_err());
        assert!(NetSpec::parse("conv 4 3\n").is_err()); // no input
        assert!(NetSpec::parse("input 1\n").is_err()); // no layers
        assert!(NetSpec::parse("input 1\nconv 4 3 3\n").is_err()); // 2 extents
    }

    #[test]
    fn f_in_at_tracks_channels() {
        let net = tiny();
        assert_eq!(net.f_in_at(0), 1);
        assert_eq!(net.f_in_at(1), 2);
        assert_eq!(net.f_in_at(2), 2);
    }
}
