//! `znni` — CLI for the ZNNi inference framework.
//!
//! Subcommands:
//!   info                      platform, topology, devices, artifacts
//!   optimize  --net NAME      run the §VI.A search, print a Table IV-style plan
//!   run       --net NAME      execute the optimized plan once, report throughput
//!   serve     --net NAME      whole-volume serving demo through the coordinator
//!   fov       --net NAME      field-of-view / valid-size info
//!
//! Common flags: --scale tiny|small|paper   --device cpu|gpu
//!               --ram GIB   --max-extent N   --extent N   --volume N
//!               --artifacts DIR

#![allow(clippy::too_many_arguments, clippy::uninlined_format_args)]

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

use znni::coordinator::{Coordinator, InferenceRequest};
use znni::device::Device;
use znni::net::{net_by_name, NetScale, NetSpec};
use znni::optimizer::{compile, make_weights, plan_table, search, CostModel, SearchSpace};
use znni::tensor::{Shape5, Tensor5};
use znni::util::pool::TaskPool;
use znni::util::{human_bytes, human_throughput};

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn get_net(flags: &HashMap<String, String>) -> Result<NetSpec> {
    let scale = match flags.get("scale").map(|s| s.as_str()) {
        Some("paper") => NetScale::Paper,
        Some("tiny") => NetScale::Tiny,
        Some("small") | None => NetScale::Small,
        Some(o) => bail!("unknown scale '{o}'"),
    };
    match flags.get("net").map(|s| s.as_str()) {
        Some("tiny") | None => Ok(znni::net::zoo::tiny_net(4)),
        Some(name) => {
            if let Some(n) = net_by_name(name, scale) {
                Ok(n)
            } else if let Ok(text) = std::fs::read_to_string(name) {
                NetSpec::parse(&text)
            } else {
                bail!("unknown net '{name}' (try n337/n537/n726/n926/tiny or a config file)")
            }
        }
    }
}

fn get_device(flags: &HashMap<String, String>) -> (Device, bool) {
    let gpu = flags.get("device").map(|d| d == "gpu").unwrap_or(false);
    let mut dev = if gpu { Device::titan_x() } else { Device::host() };
    if let Some(r) = flags.get("ram").and_then(|v| v.parse::<f64>().ok()) {
        dev.ram_bytes = (r * (1u64 << 30) as f64) as u64;
    }
    (dev, gpu)
}

fn cmd_info(flags: &HashMap<String, String>) -> Result<()> {
    println!("znni {}", znni::version());
    let topo = znni::util::pool::ChipTopology::detect();
    println!("topology: {} chip(s) x {} core(s)", topo.chips, topo.cores_per_chip);
    let host = Device::host();
    println!("host:     {} ({})", host.name, human_bytes(host.ram_bytes));
    let gpu = Device::titan_x();
    println!(
        "gpu:      {} ({}, {:.1} GB/s xfer, simulated)",
        gpu.name,
        human_bytes(gpu.ram_bytes),
        gpu.transfer_bytes_per_sec / 1e9
    );
    let dir = flags.get("artifacts").map(|s| s.as_str()).unwrap_or("artifacts");
    match znni::runtime::Runtime::open(dir) {
        Ok(rt) => {
            println!("pjrt:     platform={}", rt.platform());
            for e in &rt.manifest.entries {
                println!(
                    "artifact: {} ({} args, out {:?})",
                    e.name,
                    e.arg_shapes.len(),
                    e.output_shape
                );
            }
        }
        Err(e) => println!("pjrt:     artifacts unavailable ({e})"),
    }
    Ok(())
}

fn cmd_optimize(flags: &HashMap<String, String>) -> Result<()> {
    let net = get_net(flags)?;
    let (dev, gpu) = get_device(flags);
    let max_extent = flags
        .get("max-extent")
        .and_then(|v| v.parse().ok())
        .unwrap_or(if gpu { 49 } else { 41 });
    let pool = TaskPool::global();
    eprintln!("calibrating cost model...");
    let cm = CostModel::calibrate(pool, 10);
    let space = if gpu {
        SearchSpace::gpu_only(dev.clone(), max_extent)
    } else {
        SearchSpace::cpu_only(dev.clone(), max_extent)
    };
    let plan = search(&net, &space, &cm)
        .ok_or_else(|| anyhow!("no feasible plan under {}", human_bytes(dev.ram_bytes)))?;
    println!("net {} on {} ({}):", net.name, dev.name, human_bytes(dev.ram_bytes));
    for (k, v) in plan_table(&plan) {
        println!("  {k:<12} {v}");
    }
    println!(
        "  est: {:.3}s/patch, {} memory, {}",
        plan.est_secs,
        human_bytes(plan.est_memory),
        human_throughput(plan.est_throughput())
    );
    Ok(())
}

fn cmd_run(flags: &HashMap<String, String>) -> Result<()> {
    let net = get_net(flags)?;
    let (dev, gpu) = get_device(flags);
    let pool = TaskPool::global();
    let cm = CostModel::calibrate(pool, 10);
    let max_extent = flags.get("max-extent").and_then(|v| v.parse().ok()).unwrap_or(33);
    let mut space = if gpu {
        SearchSpace::gpu_only(dev, max_extent)
    } else {
        SearchSpace::cpu_only(dev, max_extent)
    };
    if let Some(n) = flags.get("extent").and_then(|v| v.parse().ok()) {
        space.min_extent = n;
        space.max_extent = n;
    }
    let plan = search(&net, &space, &cm).ok_or_else(|| anyhow!("no feasible plan"))?;
    let weights = make_weights(&net, 42);
    let cp = compile(&net, &plan, &weights)?;
    let mut ctx = cp.make_ctx(pool)?;
    let input = Tensor5::random(plan.input, 7);
    let t0 = std::time::Instant::now();
    let out = cp.run(input, &mut ctx);
    let secs = t0.elapsed().as_secs_f64();
    let osh = out.shape();
    let vox = (osh.s * osh.x * osh.y * osh.z) as f64;
    println!(
        "{}: input {} -> output {} in {:.3}s = {}",
        net.name,
        plan.input,
        osh,
        secs,
        human_throughput(vox / secs)
    );
    Ok(())
}

fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let net = get_net(flags)?;
    let (dev, _) = get_device(flags);
    let pool = TaskPool::global();
    let cm = CostModel::calibrate(pool, 10);
    let max_extent = flags.get("max-extent").and_then(|v| v.parse().ok()).unwrap_or(21);
    let space = SearchSpace::cpu_only(dev, max_extent);
    let plan = search(&net, &space, &cm).ok_or_else(|| anyhow!("no feasible plan"))?;
    let weights = make_weights(&net, 42);
    let cp = compile(&net, &plan, &weights)?;
    let coord = Coordinator::new(net, cp)?;
    let v = flags.get("volume").and_then(|s| s.parse().ok()).unwrap_or(32);
    let count = flags.get("requests").and_then(|s| s.parse().ok()).unwrap_or(2);
    let reqs = (0..count)
        .map(|i| InferenceRequest {
            id: i as u64,
            volume: Tensor5::random(Shape5::new(1, coord.net.f_in, v, v, v), i as u64),
        })
        .collect();
    let (resps, metrics) = coord.serve(reqs, pool)?;
    for r in &resps {
        println!("request {}: output {} ({} voxels)", r.id, r.output.shape(), r.voxels);
    }
    println!("{}", metrics.report());
    Ok(())
}

fn cmd_fov(flags: &HashMap<String, String>) -> Result<()> {
    let net = get_net(flags)?;
    let modes = vec![znni::net::PoolingMode::Mpf; net.pool_count()];
    println!("net {}: {} conv + {} pool layers", net.name, net.conv_count(), net.pool_count());
    println!("field of view: {:?}", net.field_of_view());
    println!("total stride:  {:?}", net.total_stride());
    println!("fragments (all-MPF): {}", net.fragment_factor(&modes));
    let valid = net.valid_extents(1, 64, &modes);
    println!("valid MPF input extents <= 64: {valid:?}");
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args);
    let cmd = pos.first().map(|s| s.as_str()).unwrap_or("info");
    let r = match cmd {
        "info" => cmd_info(&flags),
        "optimize" => cmd_optimize(&flags),
        "run" => cmd_run(&flags),
        "serve" => cmd_serve(&flags),
        "fov" => cmd_fov(&flags),
        other => Err(anyhow!(
            "unknown command '{other}' (try: info, optimize, run, serve, fov)"
        )),
    };
    if let Err(e) = r {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
