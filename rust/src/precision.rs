//! Reduced-precision storage tier (f16 / bf16) — a searched per-layer
//! axis.
//!
//! The paper's central thesis is that inference throughput is
//! RAM-bound: a nominally slower algorithm wins if it fits a larger
//! image (§V). Halving bytes per element is therefore a *direct*
//! throughput lever — twice the resident kernel spectra and bigger
//! patches under the same Table II budget. This module defines the
//! precision axis itself; the pieces it feeds:
//!
//! * storage: [`crate::conv::precomp::PrecomputedKernels`] can hold its
//!   spectra as f16/bf16 bit patterns (compute stays f32 — spectra are
//!   widened through arena scratch at consume time), and
//!   [`crate::layers::ConvLayer`] narrows its inter-layer activations
//!   through an arena half-buffer;
//! * kernels: the widen/narrow conversions live in [`crate::simd`]
//!   (`narrow_f16`, `widen_bf16`, `store_bias_act_narrow_*`, …) with
//!   scalar oracles and per-tier parity tests;
//! * planning: [`crate::memory::model::kernel_spectra_bytes_p`] halves
//!   the resident row, [`crate::optimizer::PlanLayer::Conv`] carries a
//!   per-layer `precision`, and `optimizer::evaluate` trades the
//!   smaller row against the widen/narrow cost from
//!   [`crate::optimizer::CostModel::convert_secs`].
//!
//! The `ZNNI_PRECISION` environment variable
//! (`f32 | f16 | bf16 | auto`, read once) gates the axis end to end;
//! [`force_precision_mode`] overrides it programmatically for tests and
//! benches. The default is `f32` — reduced precision is opt-in, because
//! unlike the kernel-spectra cache it changes numerics (within the
//! bounds documented in `docs/ARCHITECTURE.md` and enforced by
//! `tests/integration_precision.rs`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Storage precision of one layer's cached kernel spectra and output
/// activations. Compute always stays f32; this selects only how the
/// bytes at rest are encoded.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Precision {
    /// Full single precision — 4 bytes/element, bit-exact (the
    /// baseline and the accuracy oracle).
    F32 = 1,
    /// IEEE 754 binary16 — 2 bytes/element, 10 mantissa bits (relative
    /// step 2⁻¹¹), narrow dynamic range (max ≈ 65504).
    F16 = 2,
    /// bfloat16 — 2 bytes/element, 7 mantissa bits (relative step
    /// 2⁻⁸), full f32 dynamic range.
    Bf16 = 3,
}

impl Precision {
    /// Every precision, f32 first (the order the optimizer probes).
    pub const ALL: [Precision; 3] = [Precision::F32, Precision::F16, Precision::Bf16];

    /// The two half-width storage formats.
    pub const HALF: [Precision; 2] = [Precision::F16, Precision::Bf16];

    /// Bytes per stored element (4 for f32, 2 for the half formats).
    pub fn elem_bytes(self) -> u64 {
        match self {
            Precision::F32 => 4,
            Precision::F16 | Precision::Bf16 => 2,
        }
    }

    /// Whether this is a half-width storage format.
    pub fn is_half(self) -> bool {
        !matches!(self, Precision::F32)
    }

    /// Lower-case name (the `ZNNI_PRECISION` values).
    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::F16 => "f16",
            Precision::Bf16 => "bf16",
        }
    }

    /// Stable tag used in calibration profiles and bench JSON.
    pub fn tag(self) -> &'static str {
        self.name()
    }

    /// Narrow an f32 row into this format's storage bits. Must not be
    /// called for [`Precision::F32`] (f32 rows are stored as-is).
    pub fn narrow(self, dst: &mut [u16], src: &[f32]) {
        match self {
            Precision::F32 => unreachable!("f32 rows are not narrowed"),
            Precision::F16 => crate::simd::narrow_f16(dst, src),
            Precision::Bf16 => crate::simd::narrow_bf16(dst, src),
        }
    }

    /// Widen storage bits of this format back to f32 (exact). Must not
    /// be called for [`Precision::F32`].
    pub fn widen(self, dst: &mut [f32], src: &[u16]) {
        match self {
            Precision::F32 => unreachable!("f32 rows are not widened"),
            Precision::F16 => crate::simd::widen_f16(dst, src),
            Precision::Bf16 => crate::simd::widen_bf16(dst, src),
        }
    }
}

/// Who picks the storage precision, resolved once per process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum PrecisionMode {
    /// Everything stays f32 (the default — bit-exact numerics).
    F32 = 1,
    /// Force f16 storage on every conv layer.
    F16 = 2,
    /// Force bf16 storage on every conv layer.
    Bf16 = 3,
    /// The optimizer searches the axis per layer: f32 spectra where the
    /// budget admits them, half-width spectra where only those fit.
    Auto = 4,
}

impl PrecisionMode {
    /// Parse a `ZNNI_PRECISION` value.
    pub fn parse(s: &str) -> Option<PrecisionMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "off" | "full" => Some(PrecisionMode::F32),
            "f16" | "half" => Some(PrecisionMode::F16),
            "bf16" | "bfloat16" => Some(PrecisionMode::Bf16),
            "auto" => Some(PrecisionMode::Auto),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> Option<PrecisionMode> {
        match v {
            1 => Some(PrecisionMode::F32),
            2 => Some(PrecisionMode::F16),
            3 => Some(PrecisionMode::Bf16),
            4 => Some(PrecisionMode::Auto),
            _ => None,
        }
    }

    /// The per-layer candidate precisions the optimizer may consider
    /// under this mode.
    pub fn candidates(self) -> &'static [Precision] {
        match self {
            PrecisionMode::F32 => &[Precision::F32],
            PrecisionMode::F16 => &[Precision::F16],
            PrecisionMode::Bf16 => &[Precision::Bf16],
            PrecisionMode::Auto => &Precision::ALL,
        }
    }

    /// The single precision this mode pins every layer to, or `None`
    /// for [`PrecisionMode::Auto`].
    pub fn fixed(self) -> Option<Precision> {
        match self {
            PrecisionMode::F32 => Some(Precision::F32),
            PrecisionMode::F16 => Some(Precision::F16),
            PrecisionMode::Bf16 => Some(Precision::Bf16),
            PrecisionMode::Auto => None,
        }
    }
}

const MODE_UNSET: u8 = 0;
static FORCED_MODE: AtomicU8 = AtomicU8::new(MODE_UNSET);
static RESOLVED_MODE: OnceLock<PrecisionMode> = OnceLock::new();

/// The precision mode in effect: the [`force_precision_mode`]d mode if
/// set, else `ZNNI_PRECISION` (read once), else [`PrecisionMode::F32`].
pub fn precision_mode() -> PrecisionMode {
    match PrecisionMode::from_u8(FORCED_MODE.load(Ordering::Relaxed)) {
        Some(m) => m,
        None => *RESOLVED_MODE.get_or_init(|| {
            match std::env::var("ZNNI_PRECISION") {
                Ok(v) if !v.trim().is_empty() => match PrecisionMode::parse(&v) {
                    Some(m) => m,
                    None => {
                        eprintln!("znni: unknown ZNNI_PRECISION value {v:?}, using f32");
                        PrecisionMode::F32
                    }
                },
                _ => PrecisionMode::F32,
            }
        }),
    }
}

/// Force the precision mode for every subsequent decision (tests and
/// the precision benches), or restore env/default resolution with
/// `None`.
pub fn force_precision_mode(mode: Option<PrecisionMode>) {
    match mode {
        Some(m) => FORCED_MODE.store(m as u8, Ordering::Relaxed),
        None => FORCED_MODE.store(MODE_UNSET, Ordering::Relaxed),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elem_bytes_halve() {
        assert_eq!(Precision::F32.elem_bytes(), 4);
        assert_eq!(Precision::F16.elem_bytes(), 2);
        assert_eq!(Precision::Bf16.elem_bytes(), 2);
        assert!(!Precision::F32.is_half());
        assert!(Precision::F16.is_half());
        assert!(Precision::Bf16.is_half());
    }

    #[test]
    fn mode_parse() {
        // `force_precision_mode` is process-global, so flipping it here
        // would race concurrently running search tests; the force path
        // is exercised (serialized) in tests/integration_precision.rs.
        assert_eq!(PrecisionMode::parse("f32"), Some(PrecisionMode::F32));
        assert_eq!(PrecisionMode::parse("off"), Some(PrecisionMode::F32));
        assert_eq!(PrecisionMode::parse(" F16 "), Some(PrecisionMode::F16));
        assert_eq!(PrecisionMode::parse("bf16"), Some(PrecisionMode::Bf16));
        assert_eq!(PrecisionMode::parse("bfloat16"), Some(PrecisionMode::Bf16));
        assert_eq!(PrecisionMode::parse("auto"), Some(PrecisionMode::Auto));
        assert_eq!(PrecisionMode::parse("int8"), None);
    }

    #[test]
    fn candidates_follow_mode() {
        assert_eq!(PrecisionMode::F32.candidates(), &[Precision::F32]);
        assert_eq!(PrecisionMode::F16.candidates(), &[Precision::F16]);
        assert_eq!(PrecisionMode::Bf16.candidates(), &[Precision::Bf16]);
        assert_eq!(PrecisionMode::Auto.candidates(), &Precision::ALL);
        assert_eq!(PrecisionMode::Auto.fixed(), None);
        assert_eq!(PrecisionMode::F16.fixed(), Some(Precision::F16));
    }

    #[test]
    fn narrow_widen_dispatch() {
        let src = [1.0f32, -2.5, 0.0, 65519.0, 1e30];
        for p in Precision::HALF {
            let mut bits = [0u16; 5];
            p.narrow(&mut bits, &src);
            let mut back = [0.0f32; 5];
            p.widen(&mut back, &bits);
            // Exactly-representable values round-trip exactly.
            assert_eq!(back[0], 1.0);
            assert_eq!(back[1], -2.5);
            assert_eq!(back[2], 0.0);
            // Range behaviour differs by format: f16 saturates its
            // narrow range to inf, bf16 keeps the full f32 range.
            match p {
                Precision::F16 => assert!(back[4].is_infinite()),
                Precision::Bf16 => {
                    assert!(back[4].is_finite());
                    assert!((back[4] - 1e30).abs() <= 1e30 * 2.0f32.powi(-8));
                }
                Precision::F32 => unreachable!(),
            }
        }
    }
}
