//! Offline stand-in for the `anyhow` crate.
//!
//! The testbed has no crates.io access, so this vendored shim provides
//! the small API surface the workspace actually uses: [`Error`],
//! [`Result`], the [`anyhow!`] / [`bail!`] macros and the [`Context`]
//! extension trait. Errors carry a message chain only (no backtraces,
//! no downcasting) — enough for CLI reporting and tests.

use std::fmt::{self, Display};

/// A message-carrying error value.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Prepend a context line, `anyhow`-style (`context: cause`).
    pub fn context<C: Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg) }
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like real anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` coherent.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a `Result` or `Option`, converting the error side
/// into [`Error`].
pub trait Context<T> {
    fn context<C: Display>(self, c: C) -> Result<T>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Display> Context<T> for std::result::Result<T, E> {
    fn context<C: Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{c}: {e}") })
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Early-return an `Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<u32> {
        let v: u32 = s.parse()?; // via the blanket From
        if v == 0 {
            bail!("zero is not allowed");
        }
        Ok(v)
    }

    #[test]
    fn question_mark_and_bail() {
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
        assert_eq!(parse("0").unwrap_err().to_string(), "zero is not allowed");
    }

    #[test]
    fn context_chains() {
        let e: Result<()> = Err(anyhow!("inner")).context("outer");
        assert_eq!(e.unwrap_err().to_string(), "outer: inner");
        let o: Result<u32> = None.with_context(|| "missing");
        assert_eq!(o.unwrap_err().to_string(), "missing");
    }

    #[test]
    fn display_and_debug_agree() {
        let e = anyhow!("x = {}", 3);
        assert_eq!(format!("{e}"), "x = 3");
        assert_eq!(format!("{e:?}"), "x = 3");
    }
}
