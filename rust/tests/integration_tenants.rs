//! Multi-tenant serving, end to end:
//!
//! * two zoo miniatures served concurrently from ONE `TenantServer`
//!   produce outputs **bit-identical** to each tenant's own serial
//!   `Coordinator::serve` run;
//! * per-tenant admission quotas are enforced: an over-quota tenant is
//!   rejected with `OverQuota` (volume returned) while the other
//!   tenant keeps admitting;
//! * after warmup, steady-state serving with every tenant resident
//!   performs **zero** transient arena allocations;
//! * shape mismatches come back as `WrongTenantShape` naming the
//!   tenant and the shapes it accepts.

use std::sync::Arc;
use std::time::Duration;

use znni::conv::Weights;
use znni::coordinator::{Coordinator, InferenceRequest};
use znni::device::Device;
use znni::memory::model::request_memory_bytes;
use znni::net::NetSpec;
use znni::optimizer::{compile, make_weights, search, CostModel, Plan, SearchSpace};
use znni::server::tenants::{Tenant, TenantServer};
use znni::server::{RejectReason, ServerConfig};
use znni::tensor::{Shape5, Tensor5};
use znni::util::pool::{ChipTopology, TaskPool};

const EXTENT: usize = 20;

/// mini337 (FoV 15) and mini537 (FoV 18): two real zoo architectures
/// small enough for CI, big enough to have different patch shapes.
fn setup() -> (Vec<NetSpec>, Vec<Plan>, Arc<TaskPool>) {
    let minis = znni::net::zoo::bench_miniatures();
    let nets = vec![minis[0].clone(), minis[1].clone()];
    let cm = CostModel::default_rates(4);
    let mut space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 19);
    space.max_candidates = 2;
    let plans = nets.iter().map(|n| search(n, &space, &cm).expect("feasible plan")).collect();
    let pool = Arc::new(TaskPool::with_topology(ChipTopology { chips: 2, cores_per_chip: 2 }));
    (nets, plans, pool)
}

fn mk(seed: u64) -> Tensor5 {
    Tensor5::random(Shape5::new(1, 1, EXTENT, EXTENT, EXTENT), seed)
}

/// The admission currency: what one EXTENT³ request costs this net.
fn request_bytes(net: &NetSpec) -> u64 {
    request_memory_bytes(net.f_in, net.f_out(), [EXTENT; 3], net.field_of_view())
}

fn tenant_weights(nets: &[NetSpec]) -> Vec<Vec<Arc<Weights>>> {
    nets.iter().enumerate().map(|(i, n)| make_weights(n, 21 + i as u64)).collect()
}

fn build_tenants(
    nets: &[NetSpec],
    plans: &[Plan],
    weights: &[Vec<Arc<Weights>>],
    quotas: &[u64],
) -> Vec<Tenant> {
    nets.iter()
        .zip(plans)
        .zip(weights)
        .zip(quotas)
        .map(|(((net, plan), w), &quota_bytes)| Tenant {
            net: net.clone(),
            plan: compile(net, plan, w).unwrap(),
            weight: 1,
            quota_bytes,
        })
        .collect()
}

#[test]
fn concurrent_tenants_bit_identical_to_single_tenant_serial() {
    let (nets, plans, pool) = setup();
    let weights = tenant_weights(&nets);

    // Per-tenant serial reference: one request per serve call.
    let mut expect: Vec<Vec<Tensor5>> = Vec::new();
    for (ti, (net, plan)) in nets.iter().zip(&plans).enumerate() {
        let serial =
            Coordinator::new(net.clone(), compile(net, plan, &weights[ti]).unwrap()).unwrap();
        let mut outs = Vec::new();
        for i in 0..4u64 {
            let req = InferenceRequest { id: i, volume: mk(ti as u64 * 100 + i) };
            let (r, _) = serial.serve(vec![req], &pool).unwrap();
            outs.push(r.into_iter().next().unwrap().output);
        }
        expect.push(outs);
    }

    // One server, both tenants, eight concurrent clients (four each),
    // micro-batching on.
    let quotas: Vec<u64> = nets.iter().map(|n| request_bytes(n) * 8).collect();
    let cfg = ServerConfig {
        shards: 2,
        queue_depth: 4,
        max_batch_requests: 3,
        ..ServerConfig::default()
    };
    let server =
        TenantServer::start(build_tenants(&nets, &plans, &weights, &quotas), cfg, pool).unwrap();
    assert_eq!(server.tenant_names(), vec!["mini337".to_string(), "mini537".to_string()]);
    let outputs: Vec<(usize, u64, Tensor5)> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for ti in 0..nets.len() {
            for i in 0..4u64 {
                let server = &server;
                let name = nets[ti].name.as_str();
                handles.push(s.spawn(move || {
                    let mut vol = mk(ti as u64 * 100 + i);
                    loop {
                        match server.submit(name, vol) {
                            Ok(t) => return (ti, i, t.wait().expect("serve failed").output),
                            Err(rej) => {
                                assert!(
                                    matches!(
                                        rej.reason,
                                        RejectReason::QueueFull { .. }
                                            | RejectReason::OverQuota { .. }
                                    ),
                                    "unexpected rejection: {:?}",
                                    rej.reason
                                );
                                vol = rej.volume;
                                std::thread::sleep(Duration::from_micros(100));
                            }
                        }
                    }
                }));
            }
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (ti, i, got) in &outputs {
        assert_eq!(
            got.data(),
            expect[*ti][*i as usize].data(),
            "tenant {} request {i}: multi-tenant output diverged from its single-tenant run",
            nets[*ti].name
        );
    }
    let m = server.metrics();
    assert_eq!(m.merged.completed, 8);
    for (ti, net) in nets.iter().enumerate() {
        assert_eq!(m.tenants[ti].name, net.name);
        assert_eq!(m.tenants[ti].metrics.completed, 4, "{}", net.name);
        assert_eq!(
            m.tenants[ti].inflight_bytes, 0,
            "{}: quota fully released once served",
            net.name
        );
    }
}

#[test]
fn over_quota_tenant_rejected_while_other_still_admits() {
    let (nets, plans, pool) = setup();
    let weights = tenant_weights(&nets);
    // Tenant 0 gets a quota of exactly ONE request; tenant 1 is
    // generous. Quota counts queued + in-flight bytes and is released
    // only when the response (and its guard) is dropped, so a rapid
    // burst must overrun tenant 0's quota deterministically.
    let quotas = vec![request_bytes(&nets[0]), request_bytes(&nets[1]) * 32];
    let cfg = ServerConfig { shards: 1, queue_depth: 16, ..ServerConfig::default() };
    let server =
        TenantServer::start(build_tenants(&nets, &plans, &weights, &quotas), cfg, pool).unwrap();

    let mut tickets = Vec::new();
    let mut over_quota = 0u64;
    for i in 0..10u64 {
        // Interleave: tenant 1 must keep admitting while tenant 0 is
        // over quota.
        match server.submit(&nets[0].name, mk(i)) {
            Ok(t) => tickets.push(t),
            Err(rej) => {
                match &rej.reason {
                    RejectReason::OverQuota { tenant, inflight_bytes, quota } => {
                        assert_eq!(tenant, &nets[0].name);
                        assert_eq!(*quota, quotas[0]);
                        assert!(*inflight_bytes > 0, "rejection implies resident bytes");
                    }
                    other => panic!("expected OverQuota, got {other:?}"),
                }
                assert_eq!(rej.volume.shape(), mk(0).shape(), "volume returned intact");
                over_quota += 1;
            }
        }
        let t = server
            .submit(&nets[1].name, mk(100 + i))
            .expect("generous-quota tenant must admit while the other is over quota");
        tickets.push(t);
    }
    assert!(over_quota > 0, "a burst of 10 must overrun a one-request quota");
    for t in tickets {
        t.wait().unwrap();
    }
    let m = server.metrics();
    assert_eq!(m.tenants[1].metrics.completed, 10, "every admitted request completes");
    assert_eq!(m.tenants[1].metrics.rejected, 0, "tenant isolation: no collateral rejects");
    assert_eq!(m.tenants[0].metrics.rejected, over_quota);
    assert_eq!(m.tenants[0].metrics.completed + over_quota, 10);
    assert_eq!(m.tenants[0].inflight_bytes, 0);
    assert_eq!(m.tenants[1].inflight_bytes, 0);
}

#[test]
fn steady_state_multi_tenant_is_allocation_free_after_warmup() {
    let (nets, plans, pool) = setup();
    let weights = tenant_weights(&nets);
    let quotas: Vec<u64> = nets.iter().map(|n| request_bytes(n) * 8).collect();
    let cfg = ServerConfig { shards: 2, queue_depth: 16, ..ServerConfig::default() };
    let server =
        TenantServer::start(build_tenants(&nets, &plans, &weights, &quotas), cfg, pool).unwrap();
    let fresh = |server: &TenantServer| -> u64 {
        server.metrics().merged.per_shard.iter().map(|s| s.arena_fresh_allocs).sum()
    };

    // Warm until one full round (two requests per tenant, spread over
    // the shards) causes no fresh allocations.
    let mut warmed = false;
    for round in 0..12u64 {
        let before = fresh(&server);
        let tickets: Vec<_> = nets
            .iter()
            .enumerate()
            .flat_map(|(ti, net)| {
                (0..2u64).map(move |i| (net.name.clone(), ti as u64 * 100 + round * 10 + i))
            })
            .map(|(name, seed)| server.submit(&name, mk(seed)).unwrap())
            .collect();
        for t in tickets {
            t.wait().unwrap();
        }
        if round > 0 && fresh(&server) == before {
            warmed = true;
            break;
        }
    }
    assert!(warmed, "multi-tenant server never reached an allocation-free steady state");

    // The steady state must hold across a further mixed round.
    let before = fresh(&server);
    let tickets: Vec<_> = (0..3u64)
        .flat_map(|i| nets.iter().map(move |n| (n.name.clone(), 900 + i)))
        .map(|(name, seed)| server.submit(&name, mk(seed)).unwrap())
        .collect();
    for t in tickets {
        t.wait().unwrap();
    }
    assert_eq!(
        fresh(&server),
        before,
        "steady-state multi-tenant serving must perform zero transient allocations"
    );
}

#[test]
fn wrong_shape_and_unknown_tenant_are_typed_rejections() {
    let (nets, plans, pool) = setup();
    let weights = tenant_weights(&nets);
    let quotas: Vec<u64> = nets.iter().map(|n| request_bytes(n) * 4).collect();
    let cfg = ServerConfig::default();
    let server =
        TenantServer::start(build_tenants(&nets, &plans, &weights, &quotas), cfg, pool).unwrap();

    // Wrong channel count: the rejection names the tenant and the
    // shapes it accepts.
    let bad_f = Tensor5::random(Shape5::new(1, 2, EXTENT, EXTENT, EXTENT), 0);
    match server.submit("mini337", bad_f) {
        Err(rej) => match rej.reason {
            RejectReason::WrongTenantShape { tenant, f_in, min_extent, .. } => {
                assert_eq!(tenant, "mini337");
                assert_eq!(f_in, nets[0].f_in);
                assert_eq!(Some(min_extent), server.patch("mini337"));
            }
            other => panic!("expected WrongTenantShape, got {other:?}"),
        },
        Ok(_) => panic!("wrong channel count must be rejected"),
    }

    // Volume smaller than the tenant's patch.
    let tiny = Tensor5::random(Shape5::new(1, 1, 4, 4, 4), 0);
    match server.submit("mini537", tiny) {
        Err(rej) => assert!(
            matches!(rej.reason, RejectReason::WrongTenantShape { ref tenant, .. }
                if tenant == "mini537"),
            "expected WrongTenantShape for mini537, got {:?}",
            rej.reason
        ),
        Ok(_) => panic!("undersized volume must be rejected"),
    }

    // Unknown tenant: typed rejection listing who IS being served.
    match server.submit("n926", mk(0)) {
        Err(rej) => match rej.reason {
            RejectReason::BadShape { detail } => {
                assert!(detail.contains("n926") && detail.contains("mini337"), "{detail}");
            }
            other => panic!("expected BadShape for unknown tenant, got {other:?}"),
        },
        Ok(_) => panic!("unknown tenant must be rejected"),
    }
    // Nothing above admitted: no quota is held.
    for t in &server.metrics().tenants {
        assert_eq!(t.inflight_bytes, 0);
    }
}
