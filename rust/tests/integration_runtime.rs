//! Three-layer round trip: the JAX/Pallas artifacts (Layer 1–2) loaded
//! through PJRT must agree numerically with the native Rust primitives
//! (Layer 3) on identical weights. Requires `make artifacts`.

use std::sync::Arc;

use znni::conv::{Activation, Weights};
use znni::exec::ExecCtx;
use znni::layers::{ConvLayer, LayerPrimitive};
use znni::memory::model::ConvAlgo;
use znni::net::PoolingMode;
use znni::optimizer::{compile, make_weights, Plan, PlanLayer};
use znni::runtime::Runtime;
use znni::tensor::{Shape5, Tensor5};
use znni::util::pool::{ChipTopology, TaskPool};
use znni::util::quick::assert_allclose;

fn tpool() -> TaskPool {
    TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
}

fn runtime() -> Option<Runtime> {
    match Runtime::open("artifacts") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping runtime tests: {e}");
            None
        }
    }
}

#[test]
fn conv_probe_artifact_matches_native_conv() {
    let Some(rt) = runtime() else { return };
    let pool = tpool();
    // conv_probe: input (1,1,12,12,12), w (8,1,2,2,2), b (8).
    let input = Tensor5::random(Shape5::new(1, 1, 12, 12, 12), 71);
    let w = Weights::random(8, 1, [2, 2, 2], 72);
    let got = rt
        .execute_tensor("conv_probe", &input, &[w.raw(), w.raw_bias()])
        .expect("artifact executes");
    let layer = ConvLayer::new(Arc::new(w), ConvAlgo::DirectNaive, Activation::Relu);
    let mut ctx = ExecCtx::new(&pool);
    let want = layer.execute(input, &mut ctx);
    assert_eq!(got.shape(), want.shape());
    assert_allclose(got.data(), want.data(), 1e-4, 1e-3, "pallas artifact == native");
}

#[test]
fn tiny_net_artifact_matches_compiled_plan() {
    let Some(rt) = runtime() else { return };
    let pool = tpool();
    let net = znni::net::zoo::tiny_net(4);
    let weights = make_weights(&net, 73);
    let input = Tensor5::random(Shape5::new(1, 1, 13, 13, 13), 74);

    // PJRT path: x, w1, b1, w2, b2, w3, b3.
    let bufs: Vec<&[f32]> = weights
        .iter()
        .flat_map(|w| [w.raw(), w.raw_bias()])
        .collect();
    let got = rt.execute_tensor("tiny_net13", &input, &bufs).expect("net artifact executes");

    // Native path: same weights through the layer primitives.
    let modes = vec![PoolingMode::Mpf];
    let shapes = net.shapes(input.shape(), &modes).unwrap();
    let out = *shapes.last().unwrap();
    let plan = Plan {
        net_name: net.name.clone(),
        input: input.shape(),
        layers: vec![
            PlanLayer::Conv {
                algo: ConvAlgo::FftTaskParallel,
                cache_kernels: false,
                precision: znni::precision::Precision::F32,
            },
            PlanLayer::Pool { mode: PoolingMode::Mpf },
            PlanLayer::Conv {
                algo: ConvAlgo::DirectMkl,
                cache_kernels: false,
                precision: znni::precision::Precision::F32,
            },
            PlanLayer::Conv {
                algo: ConvAlgo::GpuFft,
                cache_kernels: false,
                precision: znni::precision::Precision::F32,
            },
        ],
        shapes,
        est_secs: 1.0,
        est_memory: 0,
        kernel_cache_bytes: 0,
        out_voxels: (out.s * out.x * out.y * out.z) as u64,
    };
    let cp = compile(&net, &plan, &weights).unwrap();
    let mut ctx = ExecCtx::new(&pool);
    let want = cp.run(input, &mut ctx);
    assert_eq!(got.shape(), want.shape());
    assert_allclose(got.data(), want.data(), 1e-3, 1e-2, "whole-net artifact == native");
}

#[test]
fn artifact_arg_validation() {
    let Some(rt) = runtime() else { return };
    let input = Tensor5::random(Shape5::new(1, 1, 12, 12, 12), 75);
    // Wrong arg count.
    assert!(rt.execute("conv_probe", &[input.data()]).is_err());
    // Unknown artifact.
    assert!(rt.execute("nope", &[]).is_err());
    // Wrong shape.
    let w = vec![0.0f32; 7];
    let b = vec![0.0f32; 8];
    assert!(rt.execute("conv_probe", &[input.data(), &w, &b]).is_err());
}
