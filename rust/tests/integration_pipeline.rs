//! §VII end-to-end: GPU + host RAM sub-layer execution and the CPU–GPU
//! pipeline produce the same numbers as plain execution.

use std::sync::Arc;

use znni::conv::{conv_layer_reference, Activation, Weights};
use znni::device::Device;
use znni::exec::ExecCtx;
use znni::layers::{ConvLayer, LayerPrimitive, MpfLayer, Placement};
use znni::memory::model::{conv_memory_bytes, ConvAlgo, ConvDims};
use znni::optimizer::CostModel;
use znni::pipeline::{best_theta, Pipeline};
use znni::sublayer::{decompose, execute};
use znni::tensor::{Shape5, Tensor5};
use znni::util::pool::{ChipTopology, TaskPool};
use znni::util::quick::assert_allclose;

fn tpool() -> TaskPool {
    TaskPool::with_topology(ChipTopology { chips: 2, cores_per_chip: 2 })
}

#[test]
fn gpu_host_ram_layer_equals_plain_layer_under_pressure() {
    // A layer 4× too big for the device must still compute exactly.
    let pool = tpool();
    let cm = CostModel::default_rates(pool.workers());
    let d = ConvDims { s: 1, f_in: 6, f_out: 8, n: [10, 10, 10], k: [3, 3, 3] };
    let whole = conv_memory_bytes(ConvAlgo::GpuDensePrecomp, &d, 1);
    let gpu = Device::gpu_with_ram(whole / 4);
    let plan = decompose(&d, &gpu, &cm).expect("feasible decomposition");
    assert!(plan.pieces.len() > 1);
    assert!(plan.gpu_mem <= gpu.ram_bytes);

    let input = Tensor5::random(Shape5::from_spatial(d.s, d.f_in, d.n), 5);
    let w = Weights::random(d.f_out, d.f_in, d.k, 6);
    let expect = conv_layer_reference(&input, &w, Activation::Relu);
    let mut ctx = ExecCtx::new(&pool);
    let (out, moved) = execute(&input, &w, &plan, Activation::Relu, &mut ctx);
    assert_allclose(out.data(), expect.data(), 1e-3, 1e-2, "gpu+host layer");
    assert!(moved > input.shape().bytes_f32(), "must have streamed data");
}

fn stack(seed: u64) -> Vec<Box<dyn LayerPrimitive>> {
    vec![
        Box::new(ConvLayer::new(
            Arc::new(Weights::random(3, 1, [3, 3, 3], seed)),
            ConvAlgo::FftDataParallel,
            Activation::Relu,
        )),
        Box::new(MpfLayer { window: [2, 2, 2], placement: Placement::Cpu }),
        Box::new(ConvLayer::new(
            Arc::new(Weights::random(3, 3, [3, 3, 3], seed + 1)),
            ConvAlgo::GpuFft,
            Activation::Relu,
        )),
        Box::new(ConvLayer::new(
            Arc::new(Weights::random(2, 3, [2, 2, 2], seed + 2)),
            ConvAlgo::GpuDensePrecomp,
            Activation::Relu,
        )),
    ]
}

#[test]
fn pipeline_stream_equals_sequential_for_every_theta() {
    let pool = tpool();
    for theta in 0..=4 {
        let pipe = Pipeline::split(stack(40), theta);
        let reference = Pipeline::split(stack(40), 0);
        let inputs: Vec<Tensor5> =
            (0..3).map(|i| Tensor5::random(Shape5::new(1, 1, 15, 15, 15), 60 + i)).collect();
        let inputs2: Vec<Tensor5> =
            (0..3).map(|i| Tensor5::random(Shape5::new(1, 1, 15, 15, 15), 60 + i)).collect();
        let got = pipe.run_stream(inputs, &pool);
        let want = reference.run_sequential(inputs2, &pool);
        for (g, w) in got.iter().zip(&want) {
            assert_allclose(g.data(), w.data(), 1e-3, 1e-2, &format!("theta={theta}"));
        }
    }
}

#[test]
fn theta_choice_is_consistent_with_costs() {
    // Build per-layer cost estimates and verify the chosen split is a
    // genuine argmin of max(head, tail).
    let cpu = [0.4, 1.0, 2.0, 2.0];
    let gpu = [0.2, 0.3, 0.9, 0.8];
    let theta = best_theta(&cpu, &gpu);
    let period = |t: usize| -> f64 {
        let h: f64 = cpu[..t].iter().sum();
        let g: f64 = gpu[t..].iter().sum();
        h.max(g)
    };
    for t in 0..=4 {
        assert!(period(theta) <= period(t) + 1e-12);
    }
}
