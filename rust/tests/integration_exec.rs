//! Arena-backed execution contexts: correctness and steady-state
//! allocation discipline.
//!
//! * Every primitive must be **bit-identical** under a fresh context vs
//!   a warm (reused) one — buffer recycling may never leak state.
//! * A deliberately undersized arena budget must fail loudly at *plan*
//!   time (`reserve`), never mid-execution.
//! * The compiled plan's arena sizing must stay within the optimizer's
//!   own Table II estimate, and a warm `Coordinator::serve` must
//!   perform zero transient allocations per patch (memory-ledger
//!   backed arena counters).

use std::sync::Arc;

use znni::conv::{Activation, Weights};
use znni::coordinator::{Coordinator, InferenceRequest};
use znni::device::Device;
use znni::exec::{ExecCtx, WorkspaceReq};
use znni::layers::{ConvLayer, LayerPrimitive, MaxPoolLayer, MpfLayer, Placement};
use znni::memory::model::ConvAlgo;
use znni::optimizer::{compile, make_weights, search, CostModel, SearchSpace};
use znni::tensor::{Shape5, Tensor5};
use znni::util::pool::{ChipTopology, TaskPool};

fn tpool() -> TaskPool {
    TaskPool::with_topology(ChipTopology { chips: 2, cores_per_chip: 2 })
}

/// Warm vs fresh context: every conv algorithm, max-pool and MPF must
/// produce bit-identical outputs (exact equality, not tolerance) when
/// re-run against a context whose arena already holds recycled buffers.
#[test]
fn warm_ctx_outputs_bit_identical_to_fresh() {
    let pool = tpool();
    let input = Tensor5::random(Shape5::new(2, 3, 7, 7, 7), 42);
    let w = Arc::new(Weights::random(3, 3, [3, 3, 3], 43));

    let mut layers: Vec<Box<dyn LayerPrimitive>> = ConvAlgo::ALL
        .iter()
        .map(|&algo| {
            Box::new(ConvLayer::new(w.clone(), algo, Activation::Relu)) as Box<dyn LayerPrimitive>
        })
        .collect();
    layers.push(Box::new(MpfLayer { window: [2, 2, 2], placement: Placement::Cpu }));

    for layer in &layers {
        // Fresh context, single run.
        let fresh_out = {
            let mut ctx = ExecCtx::new(&pool);
            layer.execute(input.clone_tensor(), &mut ctx)
        };
        // One context reused three times; all runs must match exactly.
        let mut warm = ExecCtx::new(&pool);
        for round in 0..3 {
            let out = layer.execute(input.clone_tensor(), &mut warm);
            assert_eq!(
                out.data(),
                fresh_out.data(),
                "{} round {round}: warm ctx output diverged",
                layer.name()
            );
            warm.retire(out);
        }
        let st = warm.arena.stats();
        assert!(st.reuses > 0, "{}: warm runs must hit the arena", layer.name());
    }

    // Max-pool needs a divisible extent; test it separately.
    let pin = Tensor5::random(Shape5::new(1, 2, 6, 6, 6), 44);
    let mp = MaxPoolLayer { window: [2, 2, 2], placement: Placement::Cpu };
    let fresh_out = {
        let mut ctx = ExecCtx::new(&pool);
        mp.execute(pin.clone_tensor(), &mut ctx)
    };
    let mut warm = ExecCtx::new(&pool);
    for _ in 0..3 {
        let out = mp.execute(pin.clone_tensor(), &mut warm);
        assert_eq!(out.data(), fresh_out.data(), "max-pool warm ctx diverged");
        warm.retire(out);
    }
}

/// A compiled plan re-run against the same warm context is bit-identical
/// and, from the second patch on, allocation-free.
#[test]
fn compiled_plan_warm_rerun_identical_and_allocation_free() {
    let pool = tpool();
    let net = znni::net::zoo::tiny_net(2);
    let cm = CostModel::default_rates(pool.workers());
    let mut space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 13);
    space.max_candidates = 1;
    let plan = search(&net, &space, &cm).unwrap();
    let weights = make_weights(&net, 5);
    let cp = compile(&net, &plan, &weights).unwrap();

    let mut ctx = cp.make_ctx(&pool).unwrap();
    let mk = || Tensor5::random(plan.input, 9);
    // Two warmup runs: the first builds the working set; holding both
    // outputs at once forces a second output-sized buffer into
    // circulation before the steady measurement.
    let first = cp.run(mk(), &mut ctx);
    let second = cp.run(mk(), &mut ctx);
    assert_eq!(first.data(), second.data(), "warm plan rerun must be bit-identical");
    ctx.retire(first);
    ctx.retire(second);
    let fresh_after_warmup = ctx.arena.stats().fresh_allocs;
    let third = cp.run(mk(), &mut ctx);
    assert_eq!(
        ctx.arena.stats().fresh_allocs,
        fresh_after_warmup,
        "steady-state plan execution must not allocate"
    );
    ctx.retire(third);
}

/// Undersized arena: the failure happens at plan (reserve) time with a
/// clear message — execution never starts.
#[test]
fn undersized_arena_fails_at_plan_time_not_mid_execution() {
    let pool = tpool();
    let net = znni::net::zoo::tiny_net(2);
    let cm = CostModel::default_rates(pool.workers());
    let mut space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 13);
    space.max_candidates = 1;
    let plan = search(&net, &space, &cm).unwrap();
    let weights = make_weights(&net, 5);
    let cp = compile(&net, &plan, &weights).unwrap();

    let req = cp.workspace_req(pool.workers());
    assert!(req.bytes > 1024);
    let mut ctx = ExecCtx::with_budget(&pool, 1024);
    let err = ctx.reserve(&req).expect_err("undersized budget must be rejected");
    let msg = err.to_string();
    assert!(msg.contains("undersized") && msg.contains("1024"), "{msg}");
    // A correctly sized budget passes the same gate.
    let mut ok = ExecCtx::with_budget(&pool, req.bytes);
    assert!(ok.reserve(&req).is_ok());
}

/// Acceptance: the arena's planned size is within the optimizer's
/// Table II estimate for the compiled plan (same thread count).
#[test]
fn planned_arena_within_optimizer_estimate() {
    let pool = tpool();
    let net = znni::net::zoo::tiny_net(2);
    let cm = CostModel::default_rates(pool.workers());
    let mut space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 15);
    space.max_candidates = 2;
    let plan = search(&net, &space, &cm).unwrap();
    let weights = make_weights(&net, 7);
    let cp = compile(&net, &plan, &weights).unwrap();
    let req: WorkspaceReq = cp.workspace_req(pool.workers());
    assert!(req.bytes > 0);
    assert!(
        req.bytes <= plan.est_memory,
        "planned arena {} exceeds the search's Table II estimate {}",
        req.bytes,
        plan.est_memory
    );
}

/// Acceptance: after a one-patch warmup, `Coordinator::serve` performs
/// zero transient Tensor5/workspace allocations per patch. The counters
/// are the memory ledger's arena instrumentation, surfaced per serve
/// call through `Metrics`.
#[test]
fn coordinator_steady_state_zero_transient_allocations() {
    let pool = tpool();
    let net = znni::net::zoo::tiny_net(2);
    let cm = CostModel::default_rates(pool.workers());
    let mut space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 15);
    space.max_candidates = 2;
    let plan = search(&net, &space, &cm).unwrap();
    let weights = make_weights(&net, 21);
    let cp = compile(&net, &plan, &weights).unwrap();
    let coord = Coordinator::new(net, cp).unwrap();

    let mk = |seed: u64| Tensor5::random(Shape5::new(1, 1, 20, 20, 20), seed);
    let (_, warm) = coord.serve(vec![InferenceRequest { id: 0, volume: mk(1) }], &pool).unwrap();
    assert!(warm.arena_fresh_allocs > 0, "cold serve builds the working set");
    assert!(warm.arena_hwm_bytes > 0);

    // Multi-patch steady round: more patches than the warmup had is
    // fine — every buffer shape repeats per patch.
    let (resp, steady) =
        coord.serve(vec![InferenceRequest { id: 1, volume: mk(2) }], &pool).unwrap();
    assert!(steady.patches >= 2, "volume must split into several patches");
    assert_eq!(
        steady.arena_fresh_allocs, 0,
        "warm serve must perform zero transient allocations per patch \
         ({} patches, hwm {})",
        steady.patches, steady.arena_hwm_bytes
    );
    // The ledger-side gauges saw the same activity.
    assert!(znni::memory::arena_hwm() >= steady.arena_hwm_bytes);
    assert!(resp[0].output.data().iter().any(|&v| v != 0.0));
}
