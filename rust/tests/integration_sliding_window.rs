//! The paper's core semantic claim (§II, §V): an MPF network plus
//! fragment recombination computes exactly the dense sliding-window
//! output — including across patch boundaries and for 2-pool nets.

use znni::exec::ExecCtx;
use znni::inference::{dense_reference, fragment_map, infer_volume, recombine};
use znni::memory::model::ConvAlgo;
use znni::net::spec::{LayerSpec, NetSpec, PoolingMode};
use znni::optimizer::{compile, make_weights, Plan, PlanLayer};
use znni::tensor::{Shape5, Tensor5};
use znni::util::pool::{ChipTopology, TaskPool};
use znni::util::quick::assert_allclose;

fn tpool() -> TaskPool {
    TaskPool::with_topology(ChipTopology { chips: 2, cores_per_chip: 2 })
}

fn manual_plan(net: &NetSpec, input: Shape5, modes: &[PoolingMode], algo: ConvAlgo) -> Plan {
    let shapes = net.shapes(input, modes).unwrap();
    let mut mi = 0;
    let layers = net
        .layers
        .iter()
        .map(|l| match l {
            LayerSpec::Conv { .. } => PlanLayer::Conv {
                algo,
                cache_kernels: false,
                precision: znni::precision::Precision::F32,
            },
            LayerSpec::Pool { .. } => {
                let m = modes[mi];
                mi += 1;
                PlanLayer::Pool { mode: m }
            }
        })
        .collect();
    let out = *shapes.last().unwrap();
    Plan {
        net_name: net.name.clone(),
        input,
        layers,
        shapes,
        est_secs: 1.0,
        est_memory: 0,
        kernel_cache_bytes: 0,
        out_voxels: (out.s * out.x * out.y * out.z) as u64,
    }
}

/// 2-pool net (like n726's topology, tiny): C3 P2 C3 P2 C2.
fn two_pool_net() -> NetSpec {
    NetSpec {
        name: "it-2pool".into(),
        f_in: 1,
        layers: vec![
            LayerSpec::Conv { f_out: 3, k: [3, 3, 3] },
            LayerSpec::Pool { p: [2, 2, 2] },
            LayerSpec::Conv { f_out: 3, k: [3, 3, 3] },
            LayerSpec::Pool { p: [2, 2, 2] },
            LayerSpec::Conv { f_out: 2, k: [2, 2, 2] },
        ],
    }
}

#[test]
fn two_pool_mpf_equals_dense_sliding_window() {
    let pool = tpool();
    let net = two_pool_net();
    let weights = make_weights(&net, 55);
    let fov = net.field_of_view();
    let modes = vec![PoolingMode::Mpf; 2];

    // Smallest valid MPF input with ≥2 windows of dense output.
    let n = net
        .valid_extents(fov[0] + 1, fov[0] + 16, &modes)
        .first()
        .copied()
        .expect("valid extent");
    let volume = Tensor5::random(Shape5::new(1, 1, n, n, n), 321);

    let plan = manual_plan(&net, volume.shape(), &modes, ConvAlgo::FftTaskParallel);
    let cp = compile(&net, &plan, &weights).unwrap();
    let mut ctx = ExecCtx::new(&pool);
    let raw = cp.run(volume.clone_tensor(), &mut ctx);
    let map = fragment_map(&net, &modes).unwrap();
    assert_eq!(map.offsets.len(), 64); // 8 × 8 fragments
    let dense = recombine(&raw, 1, &map, &mut ctx);

    let mp = vec![PoolingMode::MaxPool; 2];
    let wplan = manual_plan(&net, Shape5::from_spatial(1, 1, fov), &mp, ConvAlgo::DirectMkl);
    let wcp = compile(&net, &wplan, &weights).unwrap();
    let mut wctx = ExecCtx::new(&pool);
    let mut runner = |t: Tensor5| wcp.run(t, &mut wctx);
    let expect = dense_reference(&net, &mut runner, &volume);

    assert_allclose(dense.data(), expect.data(), 1e-3, 1e-2, "2-pool MPF == dense");
}

#[test]
fn patched_inference_equals_single_patch_all_algos() {
    let pool = tpool();
    let net = znni::net::zoo::tiny_net(2);
    let weights = make_weights(&net, 77);
    let fov = net.field_of_view();
    let modes = vec![PoolingMode::Mpf];
    let map = fragment_map(&net, &modes).unwrap();
    let volume = Tensor5::random(Shape5::new(1, 1, 19, 19, 19), 88);

    let mut results = Vec::new();
    for algo in [ConvAlgo::DirectNaive, ConvAlgo::FftDataParallel, ConvAlgo::GpuFft] {
        let mut ctx = ExecCtx::new(&pool);
        let mut run_patch = |patch: Tensor5| {
            let plan = manual_plan(&net, patch.shape(), &modes, algo);
            let cp = compile(&net, &plan, &weights).unwrap();
            let raw = cp.run(patch, &mut ctx);
            let dense = recombine(&raw, 1, &map, &mut ctx);
            ctx.retire(raw);
            dense
        };
        let out = infer_volume(&volume, fov, [15, 15, 15], 2, &mut run_patch).unwrap();
        results.push(out);
    }
    for r in &results[1..] {
        assert_allclose(r.data(), results[0].data(), 1e-3, 1e-2, "algo-independent volume");
    }
}
