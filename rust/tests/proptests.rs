//! Cross-module property tests: random nets, shapes and inputs.

use std::sync::Arc;

use znni::baselines::{run_baseline, Baseline};
use znni::conv::{conv_layer_reference, Activation, Weights};
use znni::exec::ExecCtx;
use znni::layers::{ConvLayer, LayerPrimitive};
use znni::memory::model::{conv_memory_bytes, ConvAlgo, ConvDims};
use znni::net::spec::{LayerSpec, NetSpec, PoolingMode};
use znni::optimizer::make_weights;
use znni::tensor::{Shape5, Tensor5};
use znni::util::pool::{ChipTopology, TaskPool};
use znni::util::quick::{assert_allclose, check_with, Config};

fn tpool() -> TaskPool {
    TaskPool::with_topology(ChipTopology { chips: 2, cores_per_chip: 2 })
}

#[test]
fn prop_all_conv_algorithms_agree() {
    let pool = tpool();
    let mut ctx = ExecCtx::new(&pool);
    check_with(Config { cases: 8, ..Default::default() }, "conv algos agree", |g| {
        let s = g.usize(1, 2);
        let fi = g.usize(1, 4);
        let fo = g.usize(1, 4);
        let k = [g.usize(1, 3), g.usize(1, 3), g.usize(1, 3)];
        let n = [k[0] + g.usize(0, 5), k[1] + g.usize(0, 5), k[2] + g.usize(0, 5)];
        let input = Tensor5::random(Shape5::from_spatial(s, fi, n), g.case as u64);
        let w = Arc::new(Weights::random(fo, fi, k, g.case as u64 + 1000));
        let reference = conv_layer_reference(&input, &w, Activation::Relu);
        for algo in ConvAlgo::ALL {
            let out = ConvLayer::new(w.clone(), algo, Activation::Relu)
                .execute(input.clone_tensor(), &mut ctx);
            assert_allclose(out.data(), reference.data(), 1e-3, 1e-2, algo.name());
        }
    });
}

#[test]
fn prop_memory_model_upper_bounds_measured() {
    // Table II must upper-bound the peak tensor bytes each primitive
    // actually touches (serial execution so the global ledger is ours).
    let pool = TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 1 });
    check_with(Config { cases: 6, ..Default::default() }, "memory model bound", |g| {
        let s = g.usize(1, 2);
        let fi = g.usize(1, 3);
        let fo = g.usize(1, 3);
        let k = [g.usize(1, 3), g.usize(1, 3), g.usize(1, 3)];
        let n = [k[0] + g.usize(2, 6), k[1] + g.usize(2, 6), k[2] + g.usize(2, 6)];
        let d = ConvDims { s, f_in: fi, f_out: fo, n, k };
        for algo in [
            ConvAlgo::DirectNaive,
            ConvAlgo::DirectMkl,
            ConvAlgo::FftDataParallel,
            ConvAlgo::FftTaskParallel,
            ConvAlgo::GpuFft,
        ] {
            let w = Arc::new(Weights::random(fo, fi, k, g.case as u64));
            let layer = ConvLayer::new(w, algo, Activation::Relu);
            let model = conv_memory_bytes(algo, &d, pool.workers())
                + znni::memory::model::GPU_FFT_K_BYTES;
            let input = Tensor5::random(Shape5::from_spatial(s, fi, n), 3);
            let in_bytes = input.shape().bytes_f32();
            // A cold context per measurement: arena takes then register
            // exactly like the direct allocations they replaced.
            let (_o, peak) = znni::memory::measure(|| {
                let mut ctx = ExecCtx::new(&pool);
                layer.execute(input, &mut ctx)
            });
            assert!(
                peak + in_bytes <= model,
                "{algo:?}: measured {} > model {model} (dims {d:?})",
                peak + in_bytes
            );
        }
    });
}

/// Random small all-MPF nets: every baseline and the MPF pipeline must
/// compute the same dense output.
#[test]
fn prop_random_nets_baselines_agree() {
    let pool = tpool();
    let mut ctx = ExecCtx::new(&pool);
    check_with(Config { cases: 4, ..Default::default() }, "random net baselines", |g| {
        // Random CP(C)(P)C net with small maps.
        let mut layers = vec![LayerSpec::Conv {
            f_out: g.usize(1, 3),
            k: [g.usize(1, 3); 3],
        }];
        layers.push(LayerSpec::Pool { p: [2, 2, 2] });
        if g.bool(0.5) {
            layers.push(LayerSpec::Conv { f_out: g.usize(1, 3), k: [2; 3] });
        }
        let last_f = g.usize(1, 2);
        layers.push(LayerSpec::Conv { f_out: last_f, k: [g.usize(1, 2); 3] });
        let net = NetSpec { name: format!("rand{}", g.case), f_in: 1, layers };
        let weights = make_weights(&net, g.case as u64 + 9);

        let fov = net.field_of_view();
        // Pick a valid extent a bit above the FoV that the max-pool
        // (subsampling) path also accepts in all offsets.
        let modes = vec![PoolingMode::Mpf; net.pool_count()];
        let Some(n) = net
            .valid_extents(fov[0], fov[0] + 8, &modes)
            .first()
            .copied()
        else {
            return; // no valid extent in range; skip this case
        };
        let input = Tensor5::random(Shape5::new(1, 1, n, n, n), g.case as u64 + 77);

        let reference =
            run_baseline(Baseline::NaiveCudnn, &net, &weights, &input, &mut ctx).unwrap();
        for b in [Baseline::CaffeStrided, Baseline::Elektronn, Baseline::Znn] {
            let out = run_baseline(b, &net, &weights, &input, &mut ctx).unwrap();
            assert_allclose(out.data(), reference.data(), 1e-3, 1e-2, b.name());
        }
    });
}

// ---------------------------------------------------------------------
// SIMD kernel layer: every vector tier must match the scalar oracle on
// every kernel family, including odd lengths and remainder tails.
// ---------------------------------------------------------------------

use znni::simd;
use znni::tensor::Complex32;

fn flat_c(v: &[Complex32]) -> Vec<f32> {
    v.iter().flat_map(|c| [c.re, c.im]).collect()
}

fn gen_c32(g: &mut znni::util::quick::Gen, n: usize) -> Vec<Complex32> {
    (0..n)
        .map(|_| Complex32::new(g.f32(-1.0, 1.0), g.f32(-1.0, 1.0)))
        .collect()
}

#[test]
fn prop_simd_f32_kernels_match_scalar_every_tier() {
    let tiers = simd::supported_tiers();
    check_with(Config { cases: 32, ..Default::default() }, "simd f32 parity", |g| {
        // Odd lengths force the vector remainder tails.
        let n = g.usize(0, 70);
        let src = g.vec_f32(n);
        let base = g.vec_f32(n);
        let k = g.f32(-2.0, 2.0);
        for &tier in &tiers {
            let mut want = base.clone();
            znni::simd::scalar::axpy(&mut want, &src, k);
            let mut got = base.clone();
            simd::axpy_with(tier, &mut got, &src, k);
            assert_allclose(&got, &want, 1e-6, 1e-4, &format!("axpy {tier:?} n={n}"));

            let mut want = base.clone();
            znni::simd::scalar::add_assign(&mut want, &src);
            let mut got = base.clone();
            simd::add_assign_with(tier, &mut got, &src);
            assert_allclose(&got, &want, 0.0, 0.0, &format!("add_assign {tier:?} n={n}"));

            let mut want = base.clone();
            znni::simd::scalar::max_assign(&mut want, &src);
            let mut got = base.clone();
            simd::max_assign_with(tier, &mut got, &src);
            assert_allclose(&got, &want, 0.0, 0.0, &format!("max_assign {tier:?} n={n}"));

            // The fused-conv kernels promise exact bit identity on
            // every tier (mul-then-add, no FMA) — 0.0 tolerance.
            let base1 = g.vec_f32(n);
            let (k0, k1) = (g.f32(-2.0, 2.0), g.f32(-2.0, 2.0));
            let mut want0 = base.clone();
            let mut want1 = base1.clone();
            znni::simd::scalar::axpy2(&mut want0, &mut want1, &src, k0, k1);
            let mut got0 = base.clone();
            let mut got1 = base1.clone();
            simd::axpy2_with(tier, &mut got0, &mut got1, &src, k0, k1);
            assert_allclose(&got0, &want0, 0.0, 0.0, &format!("axpy2.0 {tier:?} n={n}"));
            assert_allclose(&got1, &want1, 0.0, 0.0, &format!("axpy2.1 {tier:?} n={n}"));

            let bias = g.f32(-1.0, 1.0);
            for relu in [false, true] {
                let mut want = base.clone();
                znni::simd::scalar::store_bias_act(&mut want, &src, bias, relu);
                let mut got = base.clone();
                simd::store_bias_act_with(tier, &mut got, &src, bias, relu);
                assert_allclose(
                    &got,
                    &want,
                    0.0,
                    0.0,
                    &format!("store_bias_act {tier:?} relu={relu} n={n}"),
                );
            }
        }
    });
}

#[test]
fn prop_simd_complex_kernels_match_scalar_every_tier() {
    let tiers = simd::supported_tiers();
    check_with(Config { cases: 32, ..Default::default() }, "simd complex parity", |g| {
        let n = g.usize(0, 45);
        let a = gen_c32(g, n);
        let b = gen_c32(g, n);
        let acc = gen_c32(g, n);
        for &tier in &tiers {
            let mut want = acc.clone();
            znni::simd::scalar::mad_spectra(&mut want, &a, &b);
            let mut got = acc.clone();
            simd::mad_spectra_with(tier, &mut got, &a, &b);
            assert_allclose(
                &flat_c(&got),
                &flat_c(&want),
                1e-6,
                1e-4,
                &format!("mad_spectra {tier:?} n={n}"),
            );

            let mut want = acc.clone();
            znni::simd::scalar::cmul(&mut want, &a, &b);
            let mut got = acc.clone();
            simd::cmul_with(tier, &mut got, &a, &b);
            assert_allclose(
                &flat_c(&got),
                &flat_c(&want),
                1e-6,
                1e-4,
                &format!("cmul {tier:?} n={n}"),
            );
        }
    });
}

#[test]
fn prop_simd_butterflies_match_scalar_every_tier() {
    let tiers = simd::supported_tiers();
    check_with(Config { cases: 32, ..Default::default() }, "simd butterfly parity", |g| {
        let m = g.usize(1, 20);
        let fft_n = m * 4 * g.usize(1, 4); // a plausible transform size
        let step = g.usize(0, fft_n - 1);
        let tw: Vec<Complex32> = (0..fft_n)
            .map(|j| Complex32::cis(-2.0 * std::f64::consts::PI * j as f64 / fft_n as f64))
            .collect();
        let d2 = gen_c32(g, 2 * m);
        let d4 = gen_c32(g, 4 * m);
        for &tier in &tiers {
            let mut want = d2.clone();
            znni::simd::scalar::radix2_combine(&mut want, m, &tw, step, fft_n);
            let mut got = d2.clone();
            simd::radix2_combine_with(tier, &mut got, m, &tw, step, fft_n);
            assert_allclose(
                &flat_c(&got),
                &flat_c(&want),
                1e-6,
                1e-4,
                &format!("radix2 {tier:?} m={m}"),
            );

            let mut want = d4.clone();
            znni::simd::scalar::radix4_combine(&mut want, m, &tw, step, fft_n);
            let mut got = d4.clone();
            simd::radix4_combine_with(tier, &mut got, m, &tw, step, fft_n);
            assert_allclose(
                &flat_c(&got),
                &flat_c(&want),
                1e-6,
                1e-4,
                &format!("radix4 {tier:?} m={m}"),
            );
        }
    });
}

/// End-to-end parity: force each supported dispatch tier globally and
/// run the full primitives against the (tier-independent) scalar
/// oracles — conv via `conv_layer_reference`, pooling via
/// `pool_one_scalar`, plus an FFT round-trip.
#[test]
fn simd_forced_tiers_end_to_end() {
    let pool = tpool();
    let mut ctx = ExecCtx::new(&pool);
    for tier in simd::supported_tiers() {
        simd::force(Some(tier));
        let label = |what: &str| format!("{what} under {tier:?}");

        // Direct + FFT convolution primitives.
        let input = Tensor5::random(Shape5::new(2, 3, 7, 6, 9), 42);
        let w = Weights::random(3, 3, [3, 2, 3], 43);
        let expect = conv_layer_reference(&input, &w, Activation::Relu);
        let got = znni::conv::direct::conv_direct_mkl(&input, &w, Activation::Relu, &mut ctx);
        assert_allclose(got.data(), expect.data(), 1e-4, 1e-3, &label("direct-mkl"));
        let got = znni::conv::direct::conv_direct_naive(&input, &w, Activation::Relu, &mut ctx);
        assert_allclose(got.data(), expect.data(), 1e-4, 1e-3, &label("direct-naive"));
        let got = znni::conv::fft_tp::conv_fft_tp(
            input.clone_tensor(),
            &w,
            Activation::Relu,
            &mut ctx,
        );
        assert_allclose(got.data(), expect.data(), 1e-3, 1e-2, &label("fft-tp"));
        let got = znni::conv::fft_dp::conv_fft_dp(
            input.clone_tensor(),
            &w,
            Activation::Relu,
            &mut ctx,
        );
        assert_allclose(got.data(), expect.data(), 1e-3, 1e-2, &label("fft-dp"));

        // Pooling: max_pool against the scalar per-image oracle.
        let t = Tensor5::random(Shape5::new(1, 2, 4, 6, 8), 7);
        let mp = znni::pool::max_pool(&t, [2, 2, 2], &mut ctx);
        for f in 0..2 {
            let mut want = vec![0.0f32; 2 * 3 * 4];
            znni::pool::pool_one_scalar(
                t.image(0, f),
                [4, 6, 8],
                [2, 2, 2],
                [0, 0, 0],
                [2, 3, 4],
                &mut want,
            );
            assert_allclose(mp.image(0, f), &want, 0.0, 0.0, &label("max_pool"));
        }

        // FFT round-trip under the forced tier.
        let plan = znni::fft::Fft3::new([8, 9, 10]);
        let mut sc = znni::fft::fft3d::Fft3Scratch::new();
        let dims = [6, 7, 8];
        let img = Tensor5::random(Shape5::from_spatial(1, 1, dims), 11);
        let mut freq = vec![Complex32::ZERO; plan.complex_len()];
        plan.forward(img.image(0, 0), dims, &mut freq, &mut sc);
        let mut back = vec![0.0f32; dims[0] * dims[1] * dims[2]];
        plan.inverse_crop(&mut freq, [0, 0, 0], dims, &mut back, &mut sc);
        assert_allclose(&back, img.image(0, 0), 1e-4, 1e-3, &label("fft roundtrip"));
    }
    simd::force(None);
}

/// The fused direct-conv family's bit-identity contract: under every
/// forced SIMD tier, `conv_direct_fused` and `conv_direct_fused_pool`
/// must match their scalar oracles *exactly* — including odd extents
/// and channel/tile tails that exercise the vector remainder paths.
#[test]
fn simd_forced_tiers_fused_direct_bit_identity() {
    use znni::conv::direct_fused::{
        conv_direct_fused, conv_direct_fused_pool, conv_fused_pool_reference,
        conv_fused_reference,
    };
    let pool = tpool();
    let mut ctx = ExecCtx::new(&pool);
    for tier in simd::supported_tiers() {
        simd::force(Some(tier));
        let label = |what: &str| format!("{what} under {tier:?}");

        // Odd spatial extents and an odd f_out (register-tile tail).
        for (fo, k) in [(3usize, [3usize, 2, 3]), (4, [1, 3, 2]), (1, [2, 2, 2])] {
            let n = [k[0] + 4, k[1] + 5, k[2] + 3];
            let input = Tensor5::random(Shape5::from_spatial(2, 3, n), 51);
            let w = Weights::random(fo, 3, k, 52);
            for act in [Activation::Relu, Activation::None] {
                let want = conv_fused_reference(&input, &w, act);
                let got = conv_direct_fused(&input, &w, act, &mut ctx);
                assert_allclose(got.data(), want.data(), 0.0, 0.0, &label("fused conv"));
            }
        }

        // Fused conv→pool, windows that leave vector tails in z.
        for (fo, pw) in [(4usize, [2usize, 2, 2]), (3, [1, 2, 2]), (5, [2, 1, 3])] {
            let k = [3usize, 3, 3];
            let n = [k[0] - 1 + pw[0] * 3, k[1] - 1 + pw[1] * 3, k[2] - 1 + pw[2] * 3];
            let input = Tensor5::random(Shape5::from_spatial(1, 2, n), 53);
            let w = Weights::random(fo, 2, k, 54);
            let want = conv_fused_pool_reference(&input, &w, Activation::Relu, pw);
            let got = conv_direct_fused_pool(&input, &w, Activation::Relu, pw, &mut ctx);
            assert_allclose(got.data(), want.data(), 0.0, 0.0, &label("fused conv+pool"));
        }
    }
    simd::force(None);
}

/// Satellite parity sweep: on the conv→pool pair shapes of every zoo
/// net, the fused primitive must agree exactly with running the same
/// register-tiled conv followed by a separate max-pool.
#[test]
fn fused_pool_matches_conv_then_pool_on_zoo_cp_pairs() {
    use znni::conv::direct_fused::{conv_direct_fused, conv_direct_fused_pool};
    use znni::net::zoo::{benchmark_nets, tiny_net, NetScale};
    let pool = tpool();
    let mut ctx = ExecCtx::new(&pool);
    let mut nets = benchmark_nets(NetScale::Tiny);
    nets.push(tiny_net(2));
    let mut pairs = 0;
    for net in &nets {
        for (li, l) in net.layers.iter().enumerate() {
            let (LayerSpec::Conv { f_out, k }, Some(LayerSpec::Pool { p })) =
                (l, net.layers.get(li + 1))
            else {
                continue;
            };
            // Smallest extent where the pool window tiles the conv
            // output twice — keeps the sweep fast at zoo kernel sizes.
            let n = [
                k[0] - 1 + p[0] * 2,
                k[1] - 1 + p[1] * 2,
                k[2] - 1 + p[2] * 2,
            ];
            let f_in = net.f_in_at(li);
            let input =
                Tensor5::random(Shape5::from_spatial(1, f_in, n), li as u64 + 60);
            let w = Weights::random(*f_out, f_in, *k, li as u64 + 61);
            let conv = conv_direct_fused(&input, &w, Activation::Relu, &mut ctx);
            let want = znni::pool::max_pool(&conv, *p, &mut ctx);
            let got = conv_direct_fused_pool(&input, &w, Activation::Relu, *p, &mut ctx);
            assert_allclose(
                got.data(),
                want.data(),
                0.0,
                0.0,
                &format!("{} layer {li}", net.name),
            );
            pairs += 1;
        }
    }
    assert!(pairs >= 8, "expected every zoo CP pair to be swept, got {pairs}");
}

#[test]
fn prop_mpf_then_recombine_is_lossless_permutation() {
    // Recombination of MPF fragments of the *identity* net (no convs
    // after pooling) is max-filtering: out[u] = max over window at u.
    let pool = tpool();
    let mut ctx = ExecCtx::new(&pool);
    check_with(Config { cases: 8, ..Default::default() }, "mpf ~ max filter", |g| {
        let t = g.usize(1, 3);
        let n = 2 * t + 1;
        let input = Tensor5::random(Shape5::new(1, 1, n, n, n), g.case as u64);
        let frags = znni::pool::mpf_forward(&input, [2, 2, 2], &mut ctx);
        let net = NetSpec {
            name: "mpf-only".into(),
            f_in: 1,
            layers: vec![LayerSpec::Pool { p: [2, 2, 2] }],
        };
        let map = znni::inference::fragment_map(&net, &[PoolingMode::Mpf]).unwrap();
        let dense = znni::inference::recombine(&frags, 1, &map, &mut ctx);
        let expect = znni::baselines::max_filter(&input, [2, 2, 2], &pool);
        assert_allclose(dense.data(), expect.data(), 0.0, 0.0, "mpf == max filter");
    });
}
