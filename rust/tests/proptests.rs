//! Cross-module property tests: random nets, shapes and inputs.

use std::sync::Arc;

use znni::baselines::{run_baseline, Baseline};
use znni::conv::{conv_layer_reference, Activation, Weights};
use znni::layers::{ConvLayer, LayerPrimitive};
use znni::memory::model::{conv_memory_bytes, ConvAlgo, ConvDims};
use znni::net::spec::{LayerSpec, NetSpec, PoolingMode};
use znni::optimizer::make_weights;
use znni::tensor::{Shape5, Tensor5};
use znni::util::pool::{ChipTopology, TaskPool};
use znni::util::quick::{assert_allclose, check_with, Config};

fn tpool() -> TaskPool {
    TaskPool::with_topology(ChipTopology { chips: 2, cores_per_chip: 2 })
}

#[test]
fn prop_all_conv_algorithms_agree() {
    let pool = tpool();
    check_with(Config { cases: 8, ..Default::default() }, "conv algos agree", |g| {
        let s = g.usize(1, 2);
        let fi = g.usize(1, 4);
        let fo = g.usize(1, 4);
        let k = [g.usize(1, 3), g.usize(1, 3), g.usize(1, 3)];
        let n = [k[0] + g.usize(0, 5), k[1] + g.usize(0, 5), k[2] + g.usize(0, 5)];
        let input = Tensor5::random(Shape5::from_spatial(s, fi, n), g.case as u64);
        let w = Arc::new(Weights::random(fo, fi, k, g.case as u64 + 1000));
        let reference = conv_layer_reference(&input, &w, Activation::Relu);
        for algo in ConvAlgo::ALL {
            let out = ConvLayer::new(w.clone(), algo, Activation::Relu)
                .execute(input.clone_tensor(), &pool);
            assert_allclose(out.data(), reference.data(), 1e-3, 1e-2, algo.name());
        }
    });
}

#[test]
fn prop_memory_model_upper_bounds_measured() {
    // Table II must upper-bound the peak tensor bytes each primitive
    // actually touches (serial execution so the global ledger is ours).
    let pool = TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 1 });
    check_with(Config { cases: 6, ..Default::default() }, "memory model bound", |g| {
        let s = g.usize(1, 2);
        let fi = g.usize(1, 3);
        let fo = g.usize(1, 3);
        let k = [g.usize(1, 3), g.usize(1, 3), g.usize(1, 3)];
        let n = [k[0] + g.usize(2, 6), k[1] + g.usize(2, 6), k[2] + g.usize(2, 6)];
        let d = ConvDims { s, f_in: fi, f_out: fo, n, k };
        for algo in [
            ConvAlgo::DirectNaive,
            ConvAlgo::DirectMkl,
            ConvAlgo::FftDataParallel,
            ConvAlgo::FftTaskParallel,
            ConvAlgo::GpuFft,
        ] {
            let w = Arc::new(Weights::random(fo, fi, k, g.case as u64));
            let layer = ConvLayer::new(w, algo, Activation::Relu);
            let model = conv_memory_bytes(algo, &d, pool.workers())
                + znni::memory::model::GPU_FFT_K_BYTES;
            let input = Tensor5::random(Shape5::from_spatial(s, fi, n), 3);
            let in_bytes = input.shape().bytes_f32();
            let (_o, peak) = znni::memory::measure(|| layer.execute(input, &pool));
            assert!(
                peak + in_bytes <= model,
                "{algo:?}: measured {} > model {model} (dims {d:?})",
                peak + in_bytes
            );
        }
    });
}

/// Random small all-MPF nets: every baseline and the MPF pipeline must
/// compute the same dense output.
#[test]
fn prop_random_nets_baselines_agree() {
    let pool = tpool();
    check_with(Config { cases: 4, ..Default::default() }, "random net baselines", |g| {
        // Random CP(C)(P)C net with small maps.
        let mut layers = vec![LayerSpec::Conv {
            f_out: g.usize(1, 3),
            k: [g.usize(1, 3); 3],
        }];
        layers.push(LayerSpec::Pool { p: [2, 2, 2] });
        if g.bool(0.5) {
            layers.push(LayerSpec::Conv { f_out: g.usize(1, 3), k: [2; 3] });
        }
        let last_f = g.usize(1, 2);
        layers.push(LayerSpec::Conv { f_out: last_f, k: [g.usize(1, 2); 3] });
        let net = NetSpec { name: format!("rand{}", g.case), f_in: 1, layers };
        let weights = make_weights(&net, g.case as u64 + 9);

        let fov = net.field_of_view();
        // Pick a valid extent a bit above the FoV that the max-pool
        // (subsampling) path also accepts in all offsets.
        let modes = vec![PoolingMode::Mpf; net.pool_count()];
        let Some(n) = net
            .valid_extents(fov[0], fov[0] + 8, &modes)
            .first()
            .copied()
        else {
            return; // no valid extent in range; skip this case
        };
        let input = Tensor5::random(Shape5::new(1, 1, n, n, n), g.case as u64 + 77);

        let reference = run_baseline(Baseline::NaiveCudnn, &net, &weights, &input, &pool).unwrap();
        for b in [Baseline::CaffeStrided, Baseline::Elektronn, Baseline::Znn] {
            let out = run_baseline(b, &net, &weights, &input, &pool).unwrap();
            assert_allclose(out.data(), reference.data(), 1e-3, 1e-2, b.name());
        }
    });
}

#[test]
fn prop_mpf_then_recombine_is_lossless_permutation() {
    // Recombination of MPF fragments of the *identity* net (no convs
    // after pooling) is max-filtering: out[u] = max over window at u.
    let pool = tpool();
    check_with(Config { cases: 8, ..Default::default() }, "mpf ~ max filter", |g| {
        let t = g.usize(1, 3);
        let n = 2 * t + 1;
        let input = Tensor5::random(Shape5::new(1, 1, n, n, n), g.case as u64);
        let frags = znni::pool::mpf_forward(&input, [2, 2, 2], &pool);
        let net = NetSpec {
            name: "mpf-only".into(),
            f_in: 1,
            layers: vec![LayerSpec::Pool { p: [2, 2, 2] }],
        };
        let map = znni::inference::fragment_map(&net, &[PoolingMode::Mpf]).unwrap();
        let dense = znni::inference::recombine(&frags, 1, &map);
        let expect = znni::baselines::max_filter(&input, [2, 2, 2], &pool);
        assert_allclose(dense.data(), expect.data(), 0.0, 0.0, "mpf == max filter");
    });
}
