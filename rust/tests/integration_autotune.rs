//! Integration tests for the measured autotuner (ISSUE 4): calibration
//! profile persistence, the serving-config search under measured vs
//! default dispatch overheads, and EDF scheduling through the server's
//! public API.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use znni::device::Device;
use znni::memory::model::ConvAlgo;
use znni::net::zoo::tiny_net;
use znni::optimizer::{compile, make_weights, search, search_serving, CostModel, SearchSpace};
use znni::server::{Server, ServerConfig, ServingLoad};
use znni::tensor::{Shape5, Tensor5};
use znni::util::pool::{ChipTopology, TaskPool};

fn tpool() -> TaskPool {
    TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
}

fn host(gb: u64) -> Device {
    Device::host_with_ram(gb << 30)
}

#[test]
fn calibration_profile_round_trips_through_a_file() {
    let pool = tpool();
    let cm = CostModel::calibrate_full(&pool, &[6, 8]);
    let path = std::env::temp_dir().join(format!("znni-profile-test-{}.json", std::process::id()));
    cm.save_profile(&path).expect("save profile");
    let loaded = CostModel::load_profile(&path).expect("load profile");
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.threads, cm.threads);
    assert_eq!(loaded.pool_rate, cm.pool_rate);
    assert_eq!(loaded.dispatch_overhead_secs, cm.dispatch_overhead_secs);
    let h = host(1);
    for algo in ConvAlgo::ALL {
        assert_eq!(loaded.rate(algo, &h), cm.rate(algo, &h), "{algo:?}");
    }
}

#[test]
fn legacy_profile_without_fused_rates_loads_with_defaults() {
    // Forward compatibility (satellite of ISSUE 7): a profile saved by
    // a build that predates the fused direct-conv family has no
    // `DirectFused*` keys in its "rates" object. Loading it must
    // succeed, honour every persisted rate, and leave the fused
    // algorithms on their default rates — then a save/load round-trip
    // of the loaded model must persist and preserve the fused rates.
    let fixture = r#"{
        "version": 1,
        "threads": 2,
        "pool_rate": 250000000.0,
        "dispatch_overhead_secs": 0.00015,
        "rates": {
            "DirectN": 900000000.0,
            "DirectM": 1800000000.0,
            "FFT-DP": 1100000000.0,
            "FFT-TP": 1500000000.0,
            "CuDNN1": 800000000.0,
            "CuDNN2": 1900000000.0,
            "FFT": 1300000000.0
        }
    }"#;
    let path = std::env::temp_dir().join(format!("znni-profile-old-{}.json", std::process::id()));
    std::fs::write(&path, fixture).unwrap();
    let loaded = CostModel::load_profile(&path).expect("legacy profile must load");
    std::fs::remove_file(&path).ok();
    let h = host(1);
    assert_eq!(loaded.rate(ConvAlgo::DirectMkl, &h), 1800000000.0);
    assert_eq!(loaded.pool_rate, 250000000.0);
    let defaults = CostModel::default_rates(2);
    for algo in [ConvAlgo::DirectFused, ConvAlgo::DirectFusedPool] {
        assert_eq!(loaded.rate(algo, &h), defaults.rate(algo, &h), "{algo:?} keeps its default");
    }
    // Round-trip: the re-saved profile carries fused rates explicitly.
    let text = loaded.to_profile_json();
    assert!(text.contains("\"DirectFused\"") && text.contains("\"DirectFusedPool\""));
    let back = CostModel::from_profile_json(&text).unwrap();
    for algo in ConvAlgo::ALL {
        assert_eq!(back.rate(algo, &h), loaded.rate(algo, &h), "{algo:?}");
    }
}

#[test]
fn loading_a_missing_or_corrupt_profile_fails_cleanly() {
    assert!(CostModel::load_profile("/nonexistent/znni-profile.json").is_err());
    let path = std::env::temp_dir().join(format!("znni-profile-bad-{}.json", std::process::id()));
    std::fs::write(&path, "{\"version\": 1}").unwrap();
    assert!(CostModel::load_profile(&path).is_err());
    std::fs::remove_file(&path).ok();
}

#[test]
fn search_serving_uses_the_model_dispatch_overhead() {
    let net = tiny_net(2);
    let space = SearchSpace::cpu_only(host(4), 15);
    let load = ServingLoad { clients: 4, volume_extent: 20 };

    // Default overhead: valid config.
    let default_cm = CostModel::default_rates(4);
    let (plan_d, cfg_d) = search_serving(&net, &space, &default_cm, &load).expect("default");
    assert!(cfg_d.shards >= 1 && cfg_d.queue_depth >= 1 && cfg_d.max_batch_requests >= 1);

    // A measured (here: injected) 5 ms overhead: still a valid config,
    // and the batch-wait floor rises to the winning shard size's share
    // of the measured overhead — waiting less than one dispatch cannot
    // pay for itself.
    let slow_dispatch = CostModel::default_rates(4).with_dispatch_overhead(5e-3);
    let (plan_m, cfg_m) = search_serving(&net, &space, &slow_dispatch, &load).expect("measured");
    assert_eq!(plan_d.input, plan_m.input, "overhead must not change the per-patch plan");
    assert!(cfg_m.shards >= 1 && cfg_m.queue_depth >= 1);
    let shard_workers = (4 / cfg_m.shards).max(1);
    let floor = (5e-3 * shard_workers as f64 / 4.0).clamp(50e-6, 5e-3);
    assert!(
        cfg_m.max_batch_wait >= Duration::from_secs_f64(floor),
        "batch wait {:?} must not be below the scaled dispatch overhead {floor}s",
        cfg_m.max_batch_wait
    );
    assert!(
        cfg_m.max_batch_wait >= cfg_d.max_batch_wait,
        "a 25x larger measured overhead must not shrink the batch wait"
    );
}

#[test]
fn calibrated_model_searches_a_servable_config() {
    // End-to-end: calibrate on this machine (tiny ladder), search the
    // serving config with the measured model, start the server with it
    // and serve one request.
    let pool = Arc::new(tpool());
    let cm = CostModel::calibrate_full(&pool, &[6, 8]);
    assert!(cm.dispatch_overhead_secs > 0.0);
    let net = tiny_net(2);
    let space = SearchSpace::cpu_only(host(4), 15);
    let load = ServingLoad { clients: 2, volume_extent: 18 };
    let (plan, cfg) = search_serving(&net, &space, &cm, &load).expect("calibrated search");
    let cp = compile(&net, &plan, &make_weights(&net, 3)).unwrap();
    let server = Server::start(net, cp, cfg, pool).unwrap();
    let resp = server
        .submit(Tensor5::random(Shape5::new(1, 1, 18, 18, 18), 5))
        .expect("admitted")
        .wait()
        .expect("served");
    assert!(resp.output.data().iter().any(|&v| v != 0.0));
}

fn edf_server(queue_depth: usize) -> (Server, usize) {
    let net = tiny_net(2);
    let cm = CostModel::default_rates(2);
    let mut space = SearchSpace::cpu_only(host(4), 15);
    space.max_candidates = 2;
    let plan = search(&net, &space, &cm).unwrap();
    let cp = compile(&net, &plan, &make_weights(&net, 3)).unwrap();
    let pool = Arc::new(tpool());
    // One shard, one request per batch, no batch wait: dispatch order
    // through the queue is exactly EDF order.
    let cfg = ServerConfig {
        shards: 1,
        queue_depth,
        max_batch_requests: 1,
        max_batch_wait: Duration::ZERO,
        ..ServerConfig::default()
    };
    let extent = plan.input.x;
    (Server::start(net, cp, cfg, pool).unwrap(), extent)
}

#[test]
fn near_deadline_request_dispatches_before_earlier_far_deadline_one() {
    let (server, _) = edf_server(16);
    let mk = |seed: u64, n: usize| Tensor5::random(Shape5::new(1, 1, n, n, n), seed);

    // Occupy the single shard with a deadline-free request big enough
    // that the two probes below are both queued while it computes.
    let blocker = server.submit(mk(1, 26)).expect("blocker admitted");
    // FIFO arrival order: far-deadline first, near-deadline second.
    let far = server.submit_with_deadline(mk(2, 18), Some(Duration::from_secs(60))).unwrap();
    let near = server.submit_with_deadline(mk(3, 18), Some(Duration::from_secs(10))).unwrap();

    let finished: Arc<Mutex<Vec<(&'static str, Instant)>>> = Arc::new(Mutex::new(Vec::new()));
    std::thread::scope(|s| {
        for (label, ticket) in [("far", far), ("near", near)] {
            let finished = finished.clone();
            s.spawn(move || {
                ticket.wait().expect("served in time");
                finished.lock().unwrap().push((label, Instant::now()));
            });
        }
        blocker.wait().expect("blocker served");
    });
    let order = finished.lock().unwrap();
    let t = |label: &str| order.iter().find(|(l, _)| *l == label).map(|(_, t)| *t).unwrap();
    assert!(
        t("near") < t("far"),
        "EDF must dispatch the near-deadline request first despite later arrival"
    );
    let m = server.metrics();
    assert_eq!(m.completed, 3);
    assert_eq!(m.deadline_misses(), 0, "both deadlines were generous: {}", m.report());
}

#[test]
fn deadline_misses_increment_the_counter() {
    let (server, _) = edf_server(16);
    // A deadline the compute cannot possibly meet: either it expires in
    // the queue (dropped at dispatch) or it completes late — both are
    // misses and exactly one of the two counters advances.
    let vol = Tensor5::random(Shape5::new(1, 1, 22, 22, 22), 9);
    let ticket = server.submit_with_deadline(vol, Some(Duration::from_millis(2))).unwrap();
    let result = ticket.wait();
    let m = server.metrics();
    assert_eq!(
        m.deadline_misses(),
        1,
        "one miss expected (expired={} late={}), wait() -> {:?}",
        m.expired,
        m.completed_late,
        result.as_ref().map(|r| r.id)
    );
    assert_eq!(m.expired + m.completed_late, m.deadline_misses());
    match result {
        Ok(_) => assert_eq!(m.completed_late, 1, "an answered request past deadline is late"),
        Err(_) => assert_eq!(m.expired, 1, "a dropped request counts as expired"),
    }
}
