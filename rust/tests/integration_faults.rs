//! Chaos suite: deterministic fault injection against the sharded
//! server (the `ZNNI_FAULTS` failpoints of `znni::util::faults`).
//!
//! The invariants under test are the fault-tolerance contract:
//!
//! * **no ticket ever hangs** — every admitted request resolves with an
//!   output or a *typed* error, whatever panics inside a shard;
//! * a panicked shard is **restarted** by its supervisor (fresh warm
//!   arenas) and the server keeps accepting work;
//! * post-recovery, fault-free requests are **bit-identical** to a
//!   clean run — restarts and cache shedding never change numerics;
//! * simulated memory pressure **degrades gracefully** (halved batch
//!   cap, shed kernel-spectra cache, `MemoryPressure` shedding at
//!   admission) and fully **recovers** once pressure clears.
//!
//! The failpoint registry is process-global, so every test serializes
//! on one mutex and disarms the registry on entry and on drop (also
//! when an assertion panics). The `chaos_env_faults` test additionally
//! honours a `ZNNI_FAULTS` environment spec so CI can sweep configs.

use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use znni::conv::Weights;
use znni::device::Device;
use znni::memory::model::{request_memory_bytes, ConvAlgo};
use znni::net::NetSpec;
use znni::optimizer::{compile, make_weights, search, CostModel, Plan, SearchSpace};
use znni::server::tenants::{Tenant, TenantServer};
use znni::server::{RejectReason, ServeError, Server, ServerConfig};
use znni::tensor::{Shape5, Tensor5};
use znni::util::faults;
use znni::util::pool::{ChipTopology, TaskPool};

/// Serializes the tests: the failpoint registry and injection counters
/// are process-global, so concurrent tests would observe each other.
static SERIAL: Mutex<()> = Mutex::new(());

/// Holds the serialization lock and guarantees the registry is disarmed
/// when the test ends — including by a failed assertion, so one broken
/// test cannot leak armed faults into the next.
struct FaultGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Drop for FaultGuard {
    fn drop(&mut self) {
        faults::clear();
    }
}

fn serial() -> FaultGuard {
    // A previous test that failed while holding the lock poisons it;
    // the guard's Drop already disarmed the registry, so recovery is
    // safe.
    let g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    faults::clear();
    FaultGuard(g)
}

fn setup() -> (NetSpec, Plan, Vec<Arc<Weights>>, Arc<TaskPool>) {
    let net = znni::net::zoo::tiny_net(2);
    let cm = CostModel::default_rates(4);
    let mut space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 15);
    space.max_candidates = 2;
    let plan = search(&net, &space, &cm).expect("feasible plan");
    let weights = make_weights(&net, 77);
    let pool = Arc::new(TaskPool::with_topology(ChipTopology { chips: 2, cores_per_chip: 2 }));
    (net, plan, weights, pool)
}

/// Like [`setup`] but forces the FFT task-parallel primitive so the
/// plan carries a kernel-spectra cache (the pressure tests shed it).
fn setup_fft() -> (NetSpec, Plan, Vec<Arc<Weights>>, Arc<TaskPool>) {
    let net = znni::net::zoo::tiny_net(2);
    let cm = CostModel::default_rates(4);
    let mut space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 15);
    space.algos = vec![ConvAlgo::FftTaskParallel];
    space.max_candidates = 2;
    let plan = search(&net, &space, &cm).expect("feasible plan");
    let weights = make_weights(&net, 77);
    let pool = Arc::new(TaskPool::with_topology(ChipTopology { chips: 2, cores_per_chip: 2 }));
    (net, plan, weights, pool)
}

fn mk(seed: u64) -> Tensor5 {
    Tensor5::random(Shape5::new(1, 1, 20, 20, 20), seed)
}

/// One deterministic single-shard server (no micro-batch coalescing
/// wait, so every submit/wait pair is exactly one batch).
fn one_shard(net: &NetSpec, plan: &Plan, weights: &[Arc<Weights>], pool: &Arc<TaskPool>) -> Server {
    let cfg = ServerConfig {
        shards: 1,
        queue_depth: 8,
        max_batch_requests: 1,
        max_batch_wait: Duration::ZERO,
        ..ServerConfig::default()
    };
    Server::start(net.clone(), compile(net, plan, weights).unwrap(), cfg, pool.clone()).unwrap()
}

#[test]
fn injected_dispatch_panic_answers_typed_and_restarts() {
    let _g = serial();
    let (net, plan, weights, pool) = setup();
    let server = one_shard(&net, &plan, &weights, &pool);

    // Clean request first: proves the server works and warms the shard.
    server.submit(mk(1)).unwrap().wait().expect("clean serve");

    // Arm AFTER start (start warms kernel caches on the caller thread).
    faults::install_str("shard_dispatch:panic:1.0").unwrap();
    let t = server.submit(mk(2)).unwrap();
    match t.wait() {
        Err(ServeError::Internal { site }) => assert_eq!(site, "shard_dispatch"),
        other => panic!("killed shard must answer Internal, got {other:?}"),
    }

    // Disarm: the restarted shard keeps serving.
    faults::clear();
    server.submit(mk(3)).unwrap().wait().expect("post-restart serve");

    let m = server.metrics();
    assert!(m.panics >= 1, "panic counter must tick, got {}", m.panics);
    assert!(m.restarts >= 1, "restart counter must tick, got {}", m.restarts);
    assert_eq!(m.per_shard[0].panics, m.panics, "single shard owns every panic");
    assert_eq!(m.per_shard[0].restarts, m.restarts);
}

#[test]
fn injected_worker_panic_surfaces_with_site() {
    let _g = serial();
    let (net, plan, weights, pool) = setup();
    let server = one_shard(&net, &plan, &weights, &pool);
    server.submit(mk(1)).unwrap().wait().expect("clean serve");

    // The panic unwinds a coordinator worker thread; the explicit join
    // in `Coordinator::serve` must propagate the original payload so
    // the typed error still names the failpoint site.
    faults::install_str("worker_patch:panic:1.0").unwrap();
    match server.submit(mk(2)).unwrap().wait() {
        Err(ServeError::Internal { site }) => assert_eq!(site, "worker_patch"),
        other => panic!("killed worker must answer Internal, got {other:?}"),
    }

    faults::clear();
    server.submit(mk(3)).unwrap().wait().expect("post-restart serve");
    let m = server.metrics();
    assert!(m.panics >= 1 && m.restarts >= 1);
}

#[test]
fn post_recovery_outputs_bit_identical_to_clean_run() {
    let _g = serial();
    let (net, plan, weights, pool) = setup();
    let server = one_shard(&net, &plan, &weights, &pool);

    // Reference output from the clean server.
    let want = server.submit(mk(7)).unwrap().wait().expect("clean serve").output;

    // Kill the shard once (losing its warm arenas mid-flight).
    faults::install_str("worker_patch:panic:1.0").unwrap();
    assert!(server.submit(mk(7)).unwrap().wait().is_err());
    faults::clear();

    // The restarted shard, on fresh arenas, must reproduce the exact
    // bytes of the clean run.
    let got = server.submit(mk(7)).unwrap().wait().expect("post-restart serve").output;
    assert_eq!(got.data(), want.data(), "restart changed the numerics");
}

#[test]
fn arena_warmup_recovers_after_restart() {
    let _g = serial();
    let (net, plan, weights, pool) = setup();
    let server = one_shard(&net, &plan, &weights, &pool);
    let fresh = |server: &Server| -> u64 { server.metrics().per_shard[0].arena_fresh_allocs };

    // Reach the allocation-free steady state (PR 2 discipline).
    let mut warmed = false;
    for round in 0..12u64 {
        let before = fresh(&server);
        server.submit(mk(100 + round)).unwrap().wait().unwrap();
        if round > 0 && fresh(&server) == before {
            warmed = true;
            break;
        }
    }
    assert!(warmed, "server never reached an allocation-free steady state");

    // Kill the shard: the unwinding worker loses its checked-out arena
    // and the supervisor drops the survivors.
    faults::install_str("shard_dispatch:panic:1.0").unwrap();
    assert!(server.submit(mk(200)).unwrap().wait().is_err());
    faults::clear();

    // The restarted shard re-warms and must return to zero fresh
    // allocations per batch.
    let mut steady = false;
    for round in 0..12u64 {
        let before = fresh(&server);
        server.submit(mk(300 + round)).unwrap().wait().expect("post-restart serve");
        if fresh(&server) == before {
            steady = true;
            break;
        }
    }
    assert!(steady, "post-restart serving never returned to zero fresh allocations");
}

#[test]
fn memory_pressure_degrades_then_recovers() {
    let _g = serial();
    let (net, plan, weights, pool) = setup_fft();
    let cfg = ServerConfig {
        shards: 1,
        queue_depth: 8,
        max_batch_requests: 4,
        max_batch_wait: Duration::ZERO,
        ..ServerConfig::default()
    };
    let server =
        Server::start(net.clone(), compile(&net, &plan, &weights).unwrap(), cfg, pool).unwrap();

    // Reference output + resident cache bytes from the healthy server.
    let want = server.submit(mk(42)).unwrap().wait().expect("clean serve").output;
    let cached = server.metrics().kernel_cache_bytes;
    assert_eq!(server.metrics().current_max_batch, 4);

    // Every batch sees a failed reserve: the cap halves and the largest
    // kernel-spectra cache row is shed (when one is resident).
    faults::install_str("arena_take:reserve_fail:1.0").unwrap();
    server.submit(mk(1)).unwrap().wait().expect("pressured serve still answers");
    server.submit(mk(2)).unwrap().wait().expect("pressured serve still answers");
    let m = server.metrics();
    assert!(m.mem_pressure_events >= 2, "pressure events: {}", m.mem_pressure_events);
    assert!(m.current_max_batch <= 2, "cap must halve, got {}", m.current_max_batch);
    if cached > 0 {
        assert!(m.shed_kernel_cache_bytes > 0, "a resident cache row must be shed");
    }

    // Pressure clears: after enough clean batches the cap doubles back
    // to the configured maximum and the shed caches may rebuild.
    faults::clear();
    for i in 0..24u64 {
        server.submit(mk(500 + i)).unwrap().wait().expect("recovery serve");
    }
    assert_eq!(server.metrics().current_max_batch, 4, "cap must fully recover");

    // Degradation and recovery never change the numerics.
    let got = server.submit(mk(42)).unwrap().wait().expect("recovered serve").output;
    assert_eq!(got.data(), want.data(), "pressure cycle changed the numerics");
}

#[test]
fn memory_pressure_sheds_admission() {
    let _g = serial();
    let (net, plan, weights, pool) = setup();
    let cfg = ServerConfig {
        shards: 1,
        queue_depth: 2,
        max_batch_requests: 1,
        max_batch_wait: Duration::ZERO,
        ..ServerConfig::default()
    };
    let server =
        Server::start(net.clone(), compile(&net, &plan, &weights).unwrap(), cfg, pool).unwrap();

    // Prime: the first batch marks the server pressured (reserve_fail)
    // and the delay makes every batch slow enough to pile submits on.
    faults::install_str("shard_dispatch:delay:1.0,arena_take:reserve_fail:1.0").unwrap();
    server.submit(mk(0)).unwrap().wait().expect("pressured serve still answers");

    // Under pressure the admission depth is halved (2 → 1): a burst
    // against a slow shard must shed with `MemoryPressure`, never
    // block. Every admitted ticket must still resolve.
    let mut tickets = Vec::new();
    let mut shed = 0u64;
    'rounds: for round in 0..20u64 {
        for i in 0..8u64 {
            match server.submit(mk(1 + round * 8 + i)) {
                Ok(t) => tickets.push(t),
                Err(rej) => {
                    assert_eq!(
                        rej.reason,
                        RejectReason::MemoryPressure { depth: 1 },
                        "pressured admission must shed with the reduced depth"
                    );
                    shed += 1;
                    break 'rounds;
                }
            }
        }
    }
    for t in tickets {
        t.wait().expect("admitted requests still complete under pressure");
    }
    assert!(shed > 0, "burst against a pressured depth-1 queue must shed");
    assert!(server.metrics().rejected >= shed);
}

#[test]
fn wait_timeout_expires_then_wait_succeeds() {
    let _g = serial();
    let (net, plan, weights, pool) = setup();
    let server = one_shard(&net, &plan, &weights, &pool);

    // The delay keeps the response from arriving within the timeout.
    faults::install_str("shard_dispatch:delay:1.0").unwrap();
    let t = server.submit(mk(5)).unwrap();
    match t.wait_timeout(Duration::from_millis(1)) {
        Err(ServeError::TimedOut { waited }) => assert_eq!(waited, Duration::from_millis(1)),
        other => panic!("1ms wait against a 25ms delay must time out, got {other:?}"),
    }
    // The ticket stays valid: the request was in flight, not lost.
    let resp = t.wait().expect("delayed response still arrives");
    assert_eq!(resp.output.shape().f, net.f_out());
}

#[test]
fn chaos_env_faults() {
    let _g = serial();

    // CI sweeps real configs through the environment; locally a mixed
    // default keeps the test meaningful. (The serialized tests above
    // disarm the env config, so it is re-installed explicitly here.)
    let spec = std::env::var("ZNNI_FAULTS")
        .ok()
        .filter(|s| !s.trim().is_empty())
        .unwrap_or_else(|| "worker_patch:panic:0.25:7,arena_take:reserve_fail:0.3:13".into());

    let (net, plan, weights, pool) = setup();
    let cfg = ServerConfig {
        shards: 2,
        queue_depth: 4,
        max_batch_requests: 2,
        ..ServerConfig::default()
    };
    let server =
        Server::start(net.clone(), compile(&net, &plan, &weights).unwrap(), cfg, pool).unwrap();
    faults::install_str(&spec).expect("ZNNI_FAULTS spec must parse");

    // Closed-loop clients under chaos. The invariant is liveness with
    // typed outcomes: every request resolves as an output or a typed
    // error — no hangs, no livelocks, and rejections return the volume
    // for retry.
    let (served, errored) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..4u64)
            .map(|c| {
                let server = &server;
                s.spawn(move || {
                    let mut served = 0u64;
                    let mut errored = 0u64;
                    for r in 0..6u64 {
                        let mut vol = mk(1000 + c * 100 + r);
                        let mut attempts = 0u32;
                        loop {
                            match server.submit(vol) {
                                Ok(t) => {
                                    match t.wait() {
                                        Ok(_) => served += 1,
                                        Err(_) => errored += 1,
                                    }
                                    break;
                                }
                                Err(rej) => {
                                    attempts += 1;
                                    assert!(
                                        attempts < 10_000,
                                        "admission livelock under {:?}",
                                        rej.reason
                                    );
                                    vol = rej.volume;
                                    std::thread::sleep(Duration::from_micros(200));
                                }
                            }
                        }
                    }
                    (served, errored)
                })
            })
            .collect();
        let mut served = 0u64;
        let mut errored = 0u64;
        for h in handles {
            let (s_ok, s_err) = h.join().unwrap();
            served += s_ok;
            errored += s_err;
        }
        (served, errored)
    });
    assert_eq!(served + errored, 24, "every request must resolve exactly once");

    // After the storm: disarm and prove the server still serves clean.
    faults::clear();
    server.submit(mk(9999)).unwrap().wait().expect("post-chaos serve");
    let m = server.metrics();
    assert_eq!(m.completed, served + 1);
}

#[test]
fn chaos_env_faults_two_tenants() {
    let _g = serial();

    // CI sweeps real configs through the environment (including a mix
    // targeting shard restarts with two tenants loaded); locally a
    // restart-heavy default keeps the test meaningful.
    let spec = std::env::var("ZNNI_FAULTS")
        .ok()
        .filter(|s| !s.trim().is_empty())
        .unwrap_or_else(|| "shard_dispatch:panic:0.2:23,arena_take:reserve_fail:0.2:13".into());

    // Two zoo miniatures as tenants of one supervised server, with
    // distinct SWRR weights so the weighted dispatch path runs too.
    let minis = znni::net::zoo::bench_miniatures();
    let nets = vec![minis[0].clone(), minis[1].clone()];
    let cm = CostModel::default_rates(4);
    let mut space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 19);
    space.max_candidates = 2;
    let pool = Arc::new(TaskPool::with_topology(ChipTopology { chips: 2, cores_per_chip: 2 }));
    let mkv = |seed: u64| Tensor5::random(Shape5::new(1, 1, 20, 20, 20), seed);
    let mut tenants = Vec::new();
    for (i, net) in nets.iter().enumerate() {
        let plan = search(net, &space, &cm).expect("feasible plan");
        let w = make_weights(net, 31 + i as u64);
        let rb = request_memory_bytes(net.f_in, net.f_out(), [20; 3], net.field_of_view());
        tenants.push(Tenant {
            net: net.clone(),
            plan: compile(net, &plan, &w).unwrap(),
            weight: (i + 1) as u32,
            quota_bytes: rb * 8,
        });
    }
    let cfg = ServerConfig {
        shards: 2,
        queue_depth: 4,
        max_batch_requests: 2,
        ..ServerConfig::default()
    };
    let server = TenantServer::start(tenants, cfg, pool).unwrap();
    faults::install_str(&spec).expect("ZNNI_FAULTS spec must parse");

    // Closed-loop clients for BOTH tenants under chaos. Liveness with
    // typed outcomes, per tenant: every request resolves as an output
    // or a typed error; quota claims leak on no path, whatever panics.
    let (served, errored) = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (ti, net) in nets.iter().enumerate() {
            for c in 0..3u64 {
                let server = &server;
                let name = net.name.as_str();
                handles.push(s.spawn(move || {
                    let mut served = 0u64;
                    let mut errored = 0u64;
                    for r in 0..4u64 {
                        let mut vol = mkv(2000 + ti as u64 * 500 + c * 100 + r);
                        let mut attempts = 0u32;
                        loop {
                            match server.submit(name, vol) {
                                Ok(t) => {
                                    match t.wait() {
                                        Ok(_) => served += 1,
                                        Err(_) => errored += 1,
                                    }
                                    break;
                                }
                                Err(rej) => {
                                    attempts += 1;
                                    assert!(
                                        attempts < 10_000,
                                        "{name}: admission livelock under {:?}",
                                        rej.reason
                                    );
                                    vol = rej.volume;
                                    std::thread::sleep(Duration::from_micros(200));
                                }
                            }
                        }
                    }
                    (served, errored)
                }));
            }
        }
        let mut served = 0u64;
        let mut errored = 0u64;
        for h in handles {
            let (s_ok, s_err) = h.join().unwrap();
            served += s_ok;
            errored += s_err;
        }
        (served, errored)
    });
    assert_eq!(served + errored, 24, "every request must resolve exactly once");

    // After the storm: disarm; BOTH tenants still serve clean, and no
    // tenant's quota claim leaked through a panic or restart.
    faults::clear();
    for net in &nets {
        server.submit(&net.name, mkv(9999)).unwrap().wait().expect("post-chaos serve");
    }
    let m = server.metrics();
    assert_eq!(m.merged.completed, served + 2);
    for t in &m.tenants {
        assert_eq!(t.inflight_bytes, 0, "{}: quota fully released after chaos", t.name);
    }
}
