//! Optimizer behaviour across devices and memory budgets — the
//! structural findings of §VI (Table IV) and §II.

use znni::device::Device;
use znni::memory::model::ConvAlgo;
use znni::net::zoo::{benchmark_nets, n537, NetScale};
use znni::net::PoolingMode;
use znni::optimizer::{search, CostModel, PlanLayer, SearchSpace};

#[test]
fn mpf_beats_maxpool_when_both_allowed() {
    // §VI.B: the highest throughput always uses MPF for every pooling
    // layer. Let the search choose freely and check it picks MPF.
    let cm = CostModel::default_rates(4);
    for net in benchmark_nets(NetScale::Tiny) {
        let modes = vec![PoolingMode::Mpf; net.pool_count()];
        let min = net.min_extent(&modes).unwrap();
        let mut space = SearchSpace::cpu_only(Device::host_with_ram(8 << 30), min + 24);
        space.allow_maxpool = true;
        space.max_candidates = 4;
        let plan = search(&net, &space, &cm).unwrap();
        for l in &plan.layers {
            if let PlanLayer::Pool { mode } = l {
                assert_eq!(*mode, PoolingMode::Mpf, "{}", net.name);
            }
        }
    }
}

#[test]
fn throughput_grows_with_memory_budget() {
    // §II / Fig 7: more RAM → larger inputs → higher estimated
    // throughput (never lower).
    let cm = CostModel::default_rates(4);
    let net = n537(NetScale::Tiny);
    let min = net.min_extent(&vec![PoolingMode::Mpf; net.pool_count()]).unwrap();
    let mut last = 0.0;
    for gb in [1u64, 2, 8] {
        let mut space = SearchSpace::cpu_only(Device::host_with_ram(gb << 30), min + 48);
        space.max_candidates = 30;
        if let Some(plan) = search(&net, &space, &cm) {
            assert!(
                plan.est_throughput() >= last,
                "throughput regressed at {gb} GiB"
            );
            last = plan.est_throughput();
        }
    }
    assert!(last > 0.0);
}

#[test]
fn gpu_plans_respect_device_ram() {
    let cm = CostModel::default_rates(4);
    for net in benchmark_nets(NetScale::Small) {
        let modes = vec![PoolingMode::Mpf; net.pool_count()];
        let min = net.min_extent(&modes).unwrap();
        let mut space = SearchSpace::gpu_only(Device::titan_x(), min + 16);
        space.max_candidates = 6;
        if let Some(plan) = search(&net, &space, &cm) {
            assert!(plan.est_memory <= Device::titan_x().ram_bytes, "{}", net.name);
            for l in &plan.layers {
                if let PlanLayer::Conv { algo, .. } = l {
                    assert!(algo.is_gpu(), "{}", net.name);
                }
            }
        }
    }
}

#[test]
fn memory_frontier_prefers_lean_primitive() {
    // Table IV's observation at the first layer: under a budget where
    // the leaner primitive allows a larger input, the optimizer must
    // not pick a plan that a leaner-primitive plan strictly dominates.
    // We check the mechanism: restricting to the lean dense primitive
    // can never achieve a *larger* best input than the full space.
    let cm = CostModel::default_rates(4);
    let net = n537(NetScale::Tiny);
    let min = net.min_extent(&vec![PoolingMode::Mpf; net.pool_count()]).unwrap();
    let budget = Device::gpu_with_ram(2 << 30);
    let mut full = SearchSpace::gpu_only(budget.clone(), min + 32);
    full.max_candidates = 30;
    let plan_full = search(&net, &full, &cm).unwrap();
    let mut lean = full.clone();
    lean.algos = vec![ConvAlgo::GpuDenseNoWorkspace];
    let plan_lean = search(&net, &lean, &cm).unwrap();
    assert!(plan_full.input.x >= plan_lean.input.x);
    // And the lean-only plan fits strictly less memory per layer.
    assert!(plan_lean.est_memory <= plan_full.est_memory);
}

#[test]
fn batch_one_wins_for_multi_pool_nets() {
    // §VI.A: for ≥2-pool networks under a memory cap, S = 1 maximises
    // estimated throughput.
    let cm = CostModel::default_rates(4);
    let net = n537(NetScale::Tiny); // 3 pooling layers
    // Budget chosen so memory BINDS: larger batches can only afford
    // smaller inputs (or nothing at all) — the §II trade-off.
    let min = net.min_extent(&vec![PoolingMode::Mpf; net.pool_count()]).unwrap();
    let mut space = SearchSpace::cpu_only(Device::host_with_ram(512 << 20), min + 40);
    space.batch_sizes = vec![1, 2, 4];
    space.max_candidates = 20;
    let plan = search(&net, &space, &cm).unwrap();
    assert_eq!(plan.input.s, 1);
}
