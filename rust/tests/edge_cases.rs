//! Edge cases and failure injection across the public API.

use std::sync::Arc;

use znni::conv::{conv_layer_reference, Activation, Weights};
use znni::exec::ExecCtx;
use znni::fft::fft3d::{Fft3, Fft3Scratch};
use znni::fft::FftPlan;
use znni::layers::{ConvLayer, LayerPrimitive, MpfLayer, Placement};
use znni::memory::model::ConvAlgo;
use znni::net::spec::{LayerSpec, NetSpec, PoolingMode};
use znni::runtime::Manifest;
use znni::tensor::{Complex32, Shape5, Tensor5};
use znni::util::pool::{ChipTopology, TaskPool};
use znni::util::quick::assert_allclose;

fn tpool() -> TaskPool {
    TaskPool::with_topology(ChipTopology { chips: 1, cores_per_chip: 2 })
}

#[test]
fn fft_length_one() {
    let plan = FftPlan::new(1);
    let src = [Complex32::new(3.0, -2.0)];
    let mut dst = [Complex32::ZERO];
    plan.forward(&src, &mut dst);
    assert_eq!(dst[0], src[0]);
}

#[test]
fn fft3_degenerate_dims() {
    // Plane (z extent 1) and line (y=z=1) volumes transform correctly.
    let mut sc = Fft3Scratch::new();
    for padded in [[4, 4, 1], [6, 1, 1], [1, 1, 8]] {
        let plan = Fft3::new(padded);
        let len = padded[0] * padded[1] * padded[2];
        let img: Vec<f32> = (0..len).map(|i| i as f32 * 0.1 - 0.3).collect();
        let mut freq = vec![Complex32::ZERO; plan.complex_len()];
        plan.forward(&img, padded, &mut freq, &mut sc);
        let mut back = vec![0.0f32; len];
        plan.inverse_crop(&mut freq, [0, 0, 0], padded, &mut back, &mut sc);
        assert_allclose(&back, &img, 1e-4, 1e-3, &format!("degenerate {padded:?}"));
    }
}

#[test]
fn conv_kernel_equals_image() {
    // k == n gives a single output voxel per map.
    let pool = tpool();
    let mut ctx = ExecCtx::new(&pool);
    let input = Tensor5::random(Shape5::new(1, 2, 4, 4, 4), 1);
    let w = Arc::new(Weights::random(3, 2, [4, 4, 4], 2));
    let reference = conv_layer_reference(&input, &w, Activation::None);
    assert_eq!(reference.shape(), Shape5::new(1, 3, 1, 1, 1));
    for algo in ConvAlgo::ALL {
        let out = ConvLayer::new(w.clone(), algo, Activation::None)
            .execute(input.clone_tensor(), &mut ctx);
        assert_allclose(out.data(), reference.data(), 1e-3, 1e-2, algo.name());
    }
}

#[test]
fn mpf_window_one_is_identity_batchwise() {
    let pool = tpool();
    let mut ctx = ExecCtx::new(&pool);
    let t = Tensor5::random(Shape5::new(2, 2, 3, 3, 3), 5);
    let m = MpfLayer { window: [1, 1, 1], placement: Placement::Cpu };
    assert!(m.accepts(t.shape()));
    let out = m.execute(t.clone_tensor(), &mut ctx);
    assert_eq!(out.shape(), t.shape());
    assert_eq!(out.data(), t.data());
}

#[test]
fn anisotropic_mpf_net_roundtrip() {
    // The paper's illustration: 2×1×1 pooling windows.
    let net = NetSpec {
        name: "aniso".into(),
        f_in: 1,
        layers: vec![
            LayerSpec::Conv { f_out: 2, k: [2, 3, 3] },
            LayerSpec::Pool { p: [2, 1, 1] },
            LayerSpec::Conv { f_out: 1, k: [2, 2, 2] },
        ],
    };
    let shapes = net
        .shapes(Shape5::new(1, 1, 8, 8, 8), &[PoolingMode::Mpf])
        .unwrap();
    assert_eq!(shapes[1].s, 2); // two fragments from 2×1×1
    let map = znni::inference::fragment_map(&net, &[PoolingMode::Mpf]).unwrap();
    assert_eq!(map.offsets, vec![[0, 0, 0], [1, 0, 0]]);
    assert_eq!(map.stride, [2, 1, 1]);
}

#[test]
fn manifest_handles_empty_and_whitespace() {
    let m = Manifest::parse("").unwrap();
    assert!(m.entries.is_empty());
    let m = Manifest::parse("\n\n  \n").unwrap();
    assert!(m.entries.is_empty());
}

#[test]
fn pipeline_empty_stream() {
    let pool = tpool();
    let pipe = znni::pipeline::Pipeline::split(vec![], 0);
    let out = pipe.run_stream(vec![], &pool);
    assert!(out.is_empty());
}

#[test]
fn weights_zero_bias_default() {
    let w = Weights::zeros(2, 2, [3, 3, 3]);
    assert_eq!(w.bias(0), 0.0);
    assert_eq!(w.raw().len(), 2 * 2 * 27);
    assert!(w.raw().iter().all(|&v| v == 0.0));
}

#[test]
fn optimizer_single_extent_space() {
    // min_extent == max_extent pins the search to one size.
    let net = znni::net::zoo::tiny_net(2);
    let cm = znni::optimizer::CostModel::default_rates(2);
    let mut space = znni::optimizer::SearchSpace::cpu_only(
        znni::device::Device::host_with_ram(4 << 30),
        13,
    );
    space.min_extent = 13;
    let plan = znni::optimizer::search(&net, &space, &cm).unwrap();
    assert_eq!(plan.input.x, 13);
}

#[test]
fn coordinator_volume_equal_to_patch() {
    // A volume exactly one patch big → a single patch, full cover.
    let pool = tpool();
    let net = znni::net::zoo::tiny_net(2);
    let cm = znni::optimizer::CostModel::default_rates(2);
    let mut space = znni::optimizer::SearchSpace::cpu_only(
        znni::device::Device::host_with_ram(4 << 30),
        15,
    );
    space.min_extent = 15;
    let plan = znni::optimizer::search(&net, &space, &cm).unwrap();
    let weights = znni::optimizer::make_weights(&net, 3);
    let cp = znni::optimizer::compile(&net, &plan, &weights).unwrap();
    let coord = znni::coordinator::Coordinator::new(net, cp).unwrap();
    let vol = Tensor5::random(Shape5::new(1, 1, 15, 15, 15), 1);
    let (resp, metrics) = coord
        .serve(vec![znni::coordinator::InferenceRequest { id: 0, volume: vol }], &pool)
        .unwrap();
    assert_eq!(metrics.patches, 1);
    let osh = resp[0].output.shape();
    let fov = coord.net.field_of_view();
    assert_eq!(osh.x, 15 - fov[0] + 1);
}

#[test]
fn sublayer_single_channel_pieces() {
    // Extreme split: 1×1 channel pieces still sum to the right answer.
    let pool = tpool();
    let cm = znni::optimizer::CostModel::default_rates(2);
    let d = znni::memory::model::ConvDims {
        s: 1,
        f_in: 3,
        f_out: 3,
        n: [6, 6, 6],
        k: [3, 3, 3],
    };
    let tiny = znni::memory::model::conv_memory_bytes(
        ConvAlgo::GpuDenseNoWorkspace,
        &znni::memory::model::ConvDims { f_in: 1, f_out: 1, ..d },
        1,
    );
    let gpu = znni::device::Device::gpu_with_ram(tiny + 512);
    let plan = znni::sublayer::decompose(&d, &gpu, &cm).unwrap();
    // The search may pick any feasible block shape; it must split and
    // must respect the device budget.
    assert!(plan.pieces.len() > 1);
    assert!(plan.gpu_mem <= gpu.ram_bytes);
    let input = Tensor5::random(Shape5::from_spatial(1, 3, [6, 6, 6]), 7);
    let w = Weights::random(3, 3, [3, 3, 3], 8);
    let expect = conv_layer_reference(&input, &w, Activation::Relu);
    let mut ctx = ExecCtx::new(&pool);
    let (out, _) = znni::sublayer::execute(&input, &w, &plan, Activation::Relu, &mut ctx);
    assert_allclose(out.data(), expect.data(), 1e-3, 1e-2, "1x1 pieces");
}

#[test]
fn net_rejects_zero_layer_parse() {
    assert!(NetSpec::parse("input 1\n").is_err());
}

#[test]
fn theory_series_empty_when_no_valid_extent() {
    let net = znni::net::zoo::tiny_net(2);
    let s = znni::optimizer::theory::speedup_series(&net, &[1], 5, 4);
    assert!(s[0].points.is_empty()); // FoV is 12; nothing valid ≤ 5
}
