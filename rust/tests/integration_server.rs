//! The async batched serving frontend, end to end:
//!
//! * concurrent clients through the sharded server produce outputs
//!   **bit-identical** to serial `Coordinator::serve` on the same
//!   request stream;
//! * after warmup, steady-state serving performs **zero** transient
//!   arena allocations (the PR 2 discipline survives the server);
//! * a saturated admission queue **rejects** (returns the volume with
//!   `QueueFull`) instead of blocking;
//! * the batched server's measured voxels/s on the closed-loop load
//!   generator is at least the serial coordinator's.

use std::sync::Arc;
use std::time::Duration;

use znni::approaches::run_server;
use znni::conv::Weights;
use znni::coordinator::{Coordinator, InferenceRequest};
use znni::device::Device;
use znni::net::NetSpec;
use znni::optimizer::{compile, make_weights, search, CostModel, Plan, SearchSpace};
use znni::server::{RejectReason, Server, ServerConfig, ServingLoad};
use znni::tensor::{Shape5, Tensor5};
use znni::util::pool::{ChipTopology, TaskPool};

fn setup() -> (NetSpec, Plan, Vec<Arc<Weights>>, Arc<TaskPool>) {
    let net = znni::net::zoo::tiny_net(2);
    let cm = CostModel::default_rates(4);
    let mut space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 15);
    space.max_candidates = 2;
    let plan = search(&net, &space, &cm).expect("feasible plan");
    let weights = make_weights(&net, 77);
    let pool = Arc::new(TaskPool::with_topology(ChipTopology { chips: 2, cores_per_chip: 2 }));
    (net, plan, weights, pool)
}

fn mk(seed: u64) -> Tensor5 {
    Tensor5::random(Shape5::new(1, 1, 20, 20, 20), seed)
}

#[test]
fn concurrent_batched_serving_bit_identical_to_serial() {
    let (net, plan, weights, pool) = setup();

    // Serial reference: one request per serve call, single worker.
    let serial = Coordinator::new(net.clone(), compile(&net, &plan, &weights).unwrap()).unwrap();
    let mut expect = Vec::new();
    for i in 0..6u64 {
        let (r, _) = serial.serve(vec![InferenceRequest { id: i, volume: mk(i) }], &pool).unwrap();
        expect.push(r.into_iter().next().unwrap().output);
    }

    // Sharded server, six concurrent clients, micro-batching on.
    let cfg = ServerConfig {
        shards: 2,
        queue_depth: 4,
        max_batch_requests: 3,
        ..ServerConfig::default()
    };
    let server =
        Server::start(net.clone(), compile(&net, &plan, &weights).unwrap(), cfg, pool.clone())
            .unwrap();
    let outputs: Vec<Tensor5> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..6u64)
            .map(|i| {
                let server = &server;
                s.spawn(move || {
                    let mut vol = mk(i);
                    loop {
                        match server.submit(vol) {
                            Ok(t) => return t.wait().expect("serve failed").output,
                            Err(rej) => {
                                assert!(
                                    matches!(rej.reason, RejectReason::QueueFull { .. }),
                                    "unexpected rejection: {:?}",
                                    rej.reason
                                );
                                vol = rej.volume;
                                std::thread::sleep(Duration::from_micros(100));
                            }
                        }
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (i, (got, want)) in outputs.iter().zip(&expect).enumerate() {
        assert_eq!(got.data(), want.data(), "request {i}: batched output diverged from serial");
    }
    let m = server.metrics();
    assert_eq!(m.completed, 6);
    assert!(m.batches >= 2, "two shards must have dispatched batches");
}

#[test]
fn steady_state_serving_is_allocation_free_after_warmup() {
    let (net, plan, weights, pool) = setup();
    let cfg = ServerConfig { shards: 2, queue_depth: 16, ..ServerConfig::default() };
    let server =
        Server::start(net.clone(), compile(&net, &plan, &weights).unwrap(), cfg, pool).unwrap();
    let shard_fresh = |server: &Server| -> u64 {
        server.metrics().per_shard.iter().map(|s| s.arena_fresh_allocs).sum()
    };

    // Warm until one full round (spread over the shards by round-robin
    // admission and work stealing) causes no fresh allocations AND
    // every shard has served at least one batch.
    let mut warmed = false;
    for round in 0..12u64 {
        let before = shard_fresh(&server);
        let tickets: Vec<_> =
            (0..4u64).map(|i| server.submit(mk(100 + round * 10 + i)).unwrap()).collect();
        for t in tickets {
            t.wait().unwrap();
        }
        let all_served = server.metrics().per_shard.iter().all(|s| s.requests > 0);
        if round > 0 && all_served && shard_fresh(&server) == before {
            warmed = true;
            break;
        }
    }
    assert!(warmed, "server never reached an allocation-free steady state");

    // The steady state must hold across a further multi-request round.
    let before = shard_fresh(&server);
    let tickets: Vec<_> = (0..6u64).map(|i| server.submit(mk(500 + i)).unwrap()).collect();
    for t in tickets {
        t.wait().unwrap();
    }
    assert_eq!(
        shard_fresh(&server),
        before,
        "steady-state batched serving must perform zero transient allocations"
    );
}

#[test]
fn saturated_queue_rejects_not_blocks() {
    let (net, plan, weights, pool) = setup();
    // One slow shard, two queue slots, no batching: easy to overrun.
    let cfg = ServerConfig {
        shards: 1,
        queue_depth: 2,
        max_batch_requests: 1,
        max_batch_wait: Duration::ZERO,
        ..ServerConfig::default()
    };
    let server =
        Server::start(net.clone(), compile(&net, &plan, &weights).unwrap(), cfg, pool).unwrap();
    let mut tickets = Vec::new();
    let mut rejected = 0u64;
    for i in 0..40u64 {
        match server.submit(mk(i)) {
            Ok(t) => tickets.push(t),
            Err(rej) => {
                assert_eq!(rej.reason, RejectReason::QueueFull { depth: 2 });
                assert_eq!(rej.volume.shape(), Shape5::new(1, 1, 20, 20, 20), "volume returned");
                rejected += 1;
            }
        }
    }
    assert!(rejected > 0, "40 rapid submits must overrun a depth-2 queue");
    assert_eq!(tickets.len() as u64 + rejected, 40);
    // Everything admitted still completes; nothing was silently dropped.
    for t in tickets {
        t.wait().unwrap();
    }
    let m = server.metrics();
    assert_eq!(m.rejected, rejected);
    assert_eq!(m.completed + m.rejected, 40);
    assert!(m.queue_depth_hwm <= 2, "admission must respect the configured depth");
}

#[test]
fn wait_timeout_leaves_ticket_usable() {
    let (net, plan, weights, pool) = setup();
    let cfg = ServerConfig { shards: 1, ..ServerConfig::default() };
    let server =
        Server::start(net.clone(), compile(&net, &plan, &weights).unwrap(), cfg, pool).unwrap();
    let t = server.submit(mk(0)).unwrap();
    // A zero-length wait races nothing: the shard cannot have served a
    // full volume between submit and this call.
    match t.wait_timeout(Duration::ZERO) {
        Err(znni::server::ServeError::TimedOut { .. }) => {}
        other => panic!("zero-length wait must time out, got {other:?}"),
    }
    // The request is still in flight; the ticket redeems normally.
    let resp = t.wait().expect("response arrives after the timed-out wait");
    assert_eq!(resp.output.shape().f, net.f_out());
}

#[test]
fn batched_server_throughput_at_least_serial() {
    let (net, _plan, weights, pool) = setup();
    let host = Device::host_with_ram(4 << 30);
    let cm = CostModel::default_rates(4);
    let load = ServingLoad { clients: 3, volume_extent: 20 };
    // Timing comparison: allow a few attempts to ride out scheduler
    // noise on busy CI machines, but require a genuine win (or tie).
    let mut best_ratio = 0.0f64;
    for _ in 0..3 {
        let r = run_server(&net, &weights, &host, &cm, pool.clone(), 15, &load, 2).unwrap();
        assert_eq!(r.requests, 6, "every closed-loop request must complete");
        assert_eq!(r.expired, 0);
        assert_eq!(r.failed, 0);
        let ratio = r.throughput() / r.serial_throughput().max(1e-12);
        best_ratio = best_ratio.max(ratio);
        if best_ratio >= 1.0 {
            break;
        }
    }
    assert!(
        best_ratio >= 1.0,
        "batched server must match or beat the serial coordinator on the same \
         request stream (best ratio {best_ratio:.3})"
    );
}
