//! Reduced-precision storage end to end (ISSUE 9): f16/bf16 spectra and
//! activations as a searched per-layer axis, gated by oracle-bound
//! accuracy tests.
//!
//! The contract under test, in the paper's currency:
//!
//! * **selection** — under `ZNNI_PRECISION=auto` the optimizer keeps
//!   plans at f32 while the budget is ample and switches to a half-width
//!   spectra row exactly where the f32 row stops fitting (the acceptance
//!   criterion);
//! * **accuracy** — a compiled half-precision plan's outputs stay within
//!   the documented bounds of the f32 oracle (f16: 2e-2, bf16: 1e-1,
//!   relative with an absolute floor at |oracle| ≤ 1) on every zoo net
//!   here and on every SIMD tier this CPU supports;
//! * **determinism** — half plans are bit-stable across cold and warm
//!   contexts (narrow is round-to-nearest-even, widen is exact, and the
//!   accumulation order is fixed);
//! * **memory** — the ledger's measured peak stays within the planned
//!   `workspace_req` (whose resident row is the *halved* spectra row).
//!
//! `precision::force_precision_mode`, `simd::force`,
//! `precomp::force_cache_mode` and the process ledger are global, so
//! every test in this binary serializes on one mutex.

use std::sync::{Mutex, MutexGuard, OnceLock};

use znni::conv::precomp::{force_cache_mode, CacheMode};
use znni::device::Device;
use znni::exec::ExecCtx;
use znni::memory::model::ConvAlgo;
use znni::net::zoo::{bench_miniatures, tiny_net};
use znni::net::NetSpec;
use znni::optimizer::{compile, make_weights, search, CostModel, PlanLayer, SearchSpace};
use znni::precision::{force_precision_mode, Precision, PrecisionMode};
use znni::simd;
use znni::tensor::Tensor5;
use znni::util::pool::{ChipTopology, TaskPool};

fn guard() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    // A panicking test poisons the mutex; the remaining tests still
    // need to run serialized, so take the guard either way.
    match LOCK.get_or_init(|| Mutex::new(())).lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

fn tpool() -> TaskPool {
    TaskPool::with_topology(ChipTopology { chips: 2, cores_per_chip: 2 })
}

fn host(bytes: u64) -> Device {
    Device::host_with_ram(bytes)
}

/// The searched conv precisions of a plan, in layer order.
fn conv_precisions(plan: &znni::optimizer::Plan) -> Vec<Precision> {
    plan.layers
        .iter()
        .filter_map(|l| match l {
            PlanLayer::Conv { precision, .. } => Some(*precision),
            _ => None,
        })
        .collect()
}

/// Acceptance: with `auto` precision, an ample budget keeps every layer
/// at f32 — and tightening the budget over the same pinned patch shape
/// eventually forces a half-width spectra row (halved resident bytes)
/// before the plan goes infeasible.
#[test]
fn optimizer_selects_half_precision_under_tight_budget() {
    let _g = guard();
    force_precision_mode(Some(PrecisionMode::Auto));
    force_cache_mode(Some(CacheMode::Auto));
    let net = tiny_net(2);
    let cm = CostModel::default_rates(2);
    let mut space = SearchSpace::cpu_only(host(4 << 30), 15);
    space.algos = vec![ConvAlgo::FftTaskParallel];
    space.max_candidates = 1;
    let roomy = search(&net, &space, &cm).expect("feasible under 4 GiB");
    assert!(roomy.kernel_cache_bytes > 0, "ample RAM must cache the spectra");
    assert!(
        conv_precisions(&roomy).iter().all(|p| !p.is_half()),
        "ample RAM must stay f32 (no conversion tax): {:?}",
        roomy.layers
    );

    // Pin the patch shape and shrink the budget 1% at a time.
    space.min_extent = roomy.input.x;
    space.max_extent = roomy.input.x;
    let mut found = None;
    for pct in 1..100u64 {
        let ram = roomy.est_memory * (100 - pct) / 100;
        let mut sp = space.clone();
        sp.device = host(ram);
        let Some(p) = search(&net, &sp, &cm) else { break };
        if conv_precisions(&p).iter().any(|pr| pr.is_half()) {
            found = Some((ram, p));
            break;
        }
    }
    let (ram, half) = found.expect(
        "some tightened budget must buy a half-width spectra row before going infeasible",
    );
    assert!(half.kernel_cache_bytes > 0, "the half plan still caches");
    assert!(
        half.kernel_cache_bytes < roomy.kernel_cache_bytes,
        "half rows must shrink the resident spectra: {} vs {}",
        half.kernel_cache_bytes,
        roomy.kernel_cache_bytes
    );
    assert!(half.est_memory <= ram, "the searched plan respects the tight budget");
    assert!(half.est_secs >= roomy.est_secs, "the conversions are not free");
    force_cache_mode(None);
    force_precision_mode(None);
}

/// Fixed `ZNNI_PRECISION` modes pin every searched conv layer, and the
/// resident spectra row costs exactly half the f32 row.
#[test]
fn fixed_modes_pin_every_conv_layer_and_halve_the_row() {
    let _g = guard();
    force_cache_mode(Some(CacheMode::Auto));
    let net = tiny_net(2);
    let cm = CostModel::default_rates(2);
    let mut space = SearchSpace::cpu_only(host(4 << 30), 15);
    space.algos = vec![ConvAlgo::FftTaskParallel];
    space.max_candidates = 1;
    force_precision_mode(Some(PrecisionMode::F32));
    let full = search(&net, &space, &cm).expect("f32 feasible");
    assert!(full.kernel_cache_bytes > 0);
    for (mode, prec) in
        [(PrecisionMode::F16, Precision::F16), (PrecisionMode::Bf16, Precision::Bf16)]
    {
        force_precision_mode(Some(mode));
        let plan = search(&net, &space, &cm).expect("half feasible");
        assert_eq!(plan.input, full.input, "same pinned patch shape");
        for p in conv_precisions(&plan) {
            assert_eq!(p, prec, "{mode:?} must pin every conv layer");
        }
        assert_eq!(
            plan.kernel_cache_bytes * 2,
            full.kernel_cache_bytes,
            "{mode:?}: half row must be exactly half the f32 row"
        );
    }
    force_precision_mode(None);
    force_cache_mode(None);
}

/// Accuracy gate (the oracle-bound suite): for every zoo net here and
/// every supported SIMD tier, the compiled f16/bf16 plan's output stays
/// within the documented bound of the f32 oracle compiled from the same
/// space, weights and input. Both plans are searched with the same
/// pinned patch so they differ only in storage precision.
#[test]
fn half_plans_match_f32_oracle_on_zoo_nets_across_tiers() {
    let _g = guard();
    force_cache_mode(Some(CacheMode::Auto));
    let pool = tpool();
    let cm = CostModel::default_rates(pool.workers());
    let mut nets: Vec<NetSpec> = vec![tiny_net(2)];
    nets.extend(bench_miniatures());
    for net in &nets {
        // 25 admits every miniature's field of view (mini926 needs 21).
        let mut space = SearchSpace::cpu_only(host(4 << 30), 25);
        space.algos = vec![ConvAlgo::FftTaskParallel];
        space.max_candidates = 1;
        let weights = make_weights(net, 0xE0);
        for tier in simd::supported_tiers() {
            simd::force(Some(tier));
            force_precision_mode(Some(PrecisionMode::F32));
            let plan32 = search(net, &space, &cm).expect("f32 feasible");
            let cp32 = compile(net, &plan32, &weights).unwrap();
            let input = Tensor5::random(plan32.input, 0xE1);
            let mut ctx = ExecCtx::new(&pool);
            let oracle = cp32.run(input.clone_tensor(), &mut ctx);
            for (mode, rtol) in
                [(PrecisionMode::F16, 2e-2f32), (PrecisionMode::Bf16, 1e-1)]
            {
                force_precision_mode(Some(mode));
                let plan = search(net, &space, &cm).expect("half feasible");
                assert_eq!(plan.input, plan32.input, "{}: same patch", net.name);
                let cp = compile(net, &plan, &weights).unwrap();
                let mut hctx = ExecCtx::new(&pool);
                let got = cp.run(input.clone_tensor(), &mut hctx);
                assert_eq!(got.shape(), oracle.shape());
                for (i, (g, e)) in got.data().iter().zip(oracle.data()).enumerate() {
                    // Relative above |e| = 1, absolute below: the
                    // quantization error scales with the layer's signal
                    // norm, not a cancelled or relu-clamped output.
                    let tol = rtol * e.abs().max(1.0);
                    assert!(
                        (g - e).abs() <= tol,
                        "{} {mode:?} on {tier:?} elem {i}: {g} vs oracle {e} (tol {tol})",
                        net.name
                    );
                }
            }
            simd::force(None);
        }
    }
    force_precision_mode(None);
    force_cache_mode(None);
}

/// Round-trip exactness: widen∘narrow is idempotent — narrowing what a
/// widen produced returns identical bits, and a second widen returns
/// identical floats. Exactly-representable values survive unchanged.
#[test]
fn narrow_widen_round_trip_is_exact() {
    let _g = guard();
    let src: Vec<f32> = (0..4096).map(|i| ((i as f32) * 0.37 - 700.0).sin() * 3.0e3).collect();
    for prec in Precision::HALF {
        let mut bits1 = vec![0u16; src.len()];
        prec.narrow(&mut bits1, &src);
        let mut wide1 = vec![0.0f32; src.len()];
        prec.widen(&mut wide1, &bits1);
        let mut bits2 = vec![0u16; src.len()];
        prec.narrow(&mut bits2, &wide1);
        assert_eq!(bits1, bits2, "{prec:?}: widened values must re-narrow to the same bits");
        let mut wide2 = vec![0.0f32; src.len()];
        prec.widen(&mut wide2, &bits2);
        let a: Vec<u32> = wide1.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = wide2.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "{prec:?}: the round trip must be a fixed point");
    }
    // Values exactly representable in both half formats pass through
    // the full round trip bit-for-bit.
    let exact = [0.0f32, -0.0, 1.0, -2.5, 0.5, 256.0, -1024.0];
    for prec in Precision::HALF {
        let mut bits = [0u16; 7];
        prec.narrow(&mut bits, &exact);
        let mut back = [0.0f32; 7];
        prec.widen(&mut back, &bits);
        for (e, b) in exact.iter().zip(back) {
            assert_eq!(e.to_bits(), b.to_bits(), "{prec:?}: {e} must round-trip exactly");
        }
    }
}

/// Determinism: a compiled half-precision plan produces bit-identical
/// outputs from a cold context and from a warm (recycled-arena) context
/// run twice.
#[test]
fn half_plan_bit_stable_warm_and_cold() {
    let _g = guard();
    force_cache_mode(Some(CacheMode::Auto));
    let pool = tpool();
    let net = tiny_net(2);
    let cm = CostModel::default_rates(pool.workers());
    let mut space = SearchSpace::cpu_only(host(4 << 30), 15);
    space.algos = vec![ConvAlgo::FftTaskParallel];
    space.max_candidates = 1;
    let weights = make_weights(&net, 0xD0);
    for mode in [PrecisionMode::F16, PrecisionMode::Bf16] {
        force_precision_mode(Some(mode));
        let plan = search(&net, &space, &cm).expect("feasible");
        assert!(conv_precisions(&plan).iter().any(|p| p.is_half()), "{mode:?} plans are half");
        let cp = compile(&net, &plan, &weights).unwrap();
        let input = Tensor5::random(plan.input, 0xD1);
        let mut cold = ExecCtx::new(&pool);
        let a = cp.run(input.clone_tensor(), &mut cold);
        let mut warm = ExecCtx::new(&pool);
        let b = cp.run(input.clone_tensor(), &mut warm);
        let c = cp.run(input.clone_tensor(), &mut warm);
        let bits = |t: &Tensor5| t.data().iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
        assert_eq!(bits(&a), bits(&b), "{mode:?}: cold vs warm");
        assert_eq!(bits(&b), bits(&c), "{mode:?}: warm vs warm");
    }
    force_precision_mode(None);
    force_cache_mode(None);
}

/// Memory regression (the ledger does not lie): under a pinned f16
/// mode the planned resident row is the halved spectra row, the
/// compiled plan's `workspace_req` carries exactly that row, and the
/// measured allocation peak of a cold build + run stays within the
/// planned workspace.
#[test]
fn ledger_peak_stays_within_planned_workspace_with_half_spectra() {
    let _g = guard();
    force_cache_mode(Some(CacheMode::Auto));
    let pool = tpool();
    let net = tiny_net(2);
    let cm = CostModel::default_rates(pool.workers());
    let mut space = SearchSpace::cpu_only(host(4 << 30), 15);
    space.algos = vec![ConvAlgo::FftTaskParallel];
    space.max_candidates = 1;
    force_precision_mode(Some(PrecisionMode::F32));
    let full = search(&net, &space, &cm).expect("f32 feasible");
    force_precision_mode(Some(PrecisionMode::F16));
    let plan = search(&net, &space, &cm).expect("f16 feasible");
    assert_eq!(plan.kernel_cache_bytes * 2, full.kernel_cache_bytes, "halved resident row");
    let weights = make_weights(&net, 0xF0);
    let cp = compile(&net, &plan, &weights).unwrap();
    let req = cp.workspace_req(pool.workers());
    assert_eq!(
        req.resident_bytes, plan.kernel_cache_bytes,
        "planned resident row == searched (half) row"
    );
    assert!(req.total() <= plan.est_memory, "workspace stays within the Table II estimate");

    let input = Tensor5::random(plan.input, 0xF1);
    let input_bytes = plan.input.bytes_f32();
    let (out, peak) = znni::memory::measure(|| {
        // Cold context *and* half-cache build inside the measured
        // section: narrowed spectra register with the ledger at their
        // 2-byte width.
        let mut ctx = cp.make_ctx(&pool).expect("budget admits the plan");
        cp.run(input, &mut ctx)
    });
    assert_eq!(cp.kernel_cache_bytes(), plan.kernel_cache_bytes, "built == planned (half)");
    assert!(
        peak + input_bytes <= req.total() + input_bytes,
        "measured peak {peak} exceeds planned workspace {} + resident row {}",
        req.bytes,
        req.resident_bytes
    );
    assert_eq!(out.shape(), *plan.shapes.last().unwrap());
    force_precision_mode(None);
    force_cache_mode(None);
}
