//! The weight-spectrum cache end to end (ISSUE 5): precomputed kernel
//! spectra must be bit-identical to on-the-fly transforms for every FFT
//! family on every supported SIMD tier, the memory ledger must see
//! exactly the planned `workspace_req + kernel-spectra row`, and the
//! optimizer must treat caching as a searched, budgeted decision.
//!
//! `simd::force`, `precomp::force_cache_mode` and the process ledger are
//! global, so every test in this binary that touches them serializes on
//! one mutex.

use std::sync::{Arc, Mutex, MutexGuard, OnceLock};

use znni::conv::precomp::{force_cache_mode, CacheMode, PrecomputedKernels, SpectraLayout};
use znni::conv::{conv_layer_reference, Activation, Weights};
use znni::exec::ExecCtx;
use znni::layers::{ConvLayer, LayerPrimitive};
use znni::memory::model::ConvAlgo;
use znni::net::zoo::tiny_net;
use znni::optimizer::{compile, make_weights, search, CostModel, PlanLayer, SearchSpace};
use znni::simd;
use znni::tensor::{Shape5, Tensor5};
use znni::util::pool::{ChipTopology, TaskPool};
use znni::util::quick::assert_allclose;

fn guard() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    // A panicking test poisons the mutex; the remaining tests still
    // need to run serialized, so take the guard either way.
    match LOCK.get_or_init(|| Mutex::new(())).lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

fn tpool() -> TaskPool {
    TaskPool::with_topology(ChipTopology { chips: 2, cores_per_chip: 2 })
}

const FFT_FAMILIES: [ConvAlgo; 3] =
    [ConvAlgo::FftDataParallel, ConvAlgo::FftTaskParallel, ConvAlgo::GpuFft];

/// Acceptance: cached-kernel execution is bit-identical to on-the-fly
/// for all three FFT primitives, across every SIMD tier this CPU
/// supports, including warm-ctx reuse across calls (the second round
/// runs entirely out of recycled arena buffers on both paths).
#[test]
fn cached_spectra_bit_identical_across_tiers_and_warm_reuse() {
    let _g = guard();
    force_cache_mode(Some(CacheMode::Auto));
    let pool = tpool();
    for algo in FFT_FAMILIES {
        for tier in simd::supported_tiers() {
            simd::force(Some(tier));
            // Fresh layers per tier: the cache must be built under the
            // same tier the on-the-fly path transforms with.
            let w = Arc::new(Weights::random(4, 3, [3, 2, 3], 91));
            let plain = ConvLayer::new(w.clone(), algo, Activation::Relu);
            let cached = ConvLayer::new(w.clone(), algo, Activation::Relu).with_kernel_cache(true);
            let input = Tensor5::random(Shape5::new(2, 3, 7, 8, 9), 17);
            let mut ctx = ExecCtx::new(&pool);
            for round in 0..2 {
                let a = plain.execute(input.clone_tensor(), &mut ctx);
                let b = cached.execute(input.clone_tensor(), &mut ctx);
                assert_eq!(
                    a.data(),
                    b.data(),
                    "{algo:?} on {tier:?} round {round}: cached != recomputed"
                );
                if round == 0 {
                    // And both are *correct*, not just mutually equal.
                    let expect = conv_layer_reference(&input, &w, Activation::Relu);
                    assert_allclose(b.data(), expect.data(), 1e-3, 1e-2, "cached vs reference");
                }
                ctx.retire(a);
                ctx.retire(b);
            }
            assert!(cached.kernel_cache_bytes() > 0, "{algo:?}: cache must be resident");
            simd::force(None);
        }
    }
    force_cache_mode(None);
}

/// A cache built for one padded FFT shape must not poison executions at
/// another shape — the primitive falls back to on-the-fly transforms.
#[test]
fn mismatched_shape_falls_back_to_recompute() {
    let _g = guard();
    force_cache_mode(Some(CacheMode::Auto));
    let pool = tpool();
    for algo in FFT_FAMILIES {
        let w = Arc::new(Weights::random(3, 2, [3, 3, 3], 5));
        let cached = ConvLayer::new(w.clone(), algo, Activation::None).with_kernel_cache(true);
        // Build the cache at 8³ …
        cached.warm(Shape5::new(1, 2, 8, 8, 8), &pool);
        let built = cached.kernel_cache_bytes();
        assert!(built > 0);
        // … then execute at 11³: the padded shape differs, so the layer
        // must recompute (and still be correct).
        let input = Tensor5::random(Shape5::new(1, 2, 11, 11, 11), 6);
        let mut ctx = ExecCtx::new(&pool);
        let out = cached.execute(input.clone_tensor(), &mut ctx);
        let expect = conv_layer_reference(&input, &w, Activation::None);
        assert_allclose(out.data(), expect.data(), 1e-3, 1e-2, "fallback correctness");
        assert_eq!(cached.kernel_cache_bytes(), built, "no rebuild at the wrong shape");
    }
    force_cache_mode(None);
}

/// The `ZNNI_KERNEL_CACHE` kill switch (forced programmatically here):
/// `off` must keep even an enabled layer from building spectra.
#[test]
fn off_mode_disables_enabled_layers() {
    let _g = guard();
    force_cache_mode(Some(CacheMode::Off));
    let pool = tpool();
    let w = Arc::new(Weights::random(2, 2, [3, 3, 3], 7));
    let layer = ConvLayer::new(w, ConvAlgo::FftTaskParallel, Activation::Relu)
        .with_kernel_cache(true);
    layer.warm(Shape5::new(1, 2, 9, 9, 9), &pool);
    assert_eq!(layer.kernel_cache_bytes(), 0, "off mode must build nothing");
    let mut ctx = ExecCtx::new(&pool);
    let out = layer.execute(Tensor5::random(Shape5::new(1, 2, 9, 9, 9), 8), &mut ctx);
    assert_eq!(layer.kernel_cache_bytes(), 0, "execute must not build under off mode");
    ctx.retire(out);
    force_cache_mode(None);
}

/// Memory-model regression (acceptance): with caching enabled, the
/// ledger's measured peak stays within `workspace_req` plus the new
/// kernel-spectra row — no hidden allocations — and an undersized arena
/// budget still fails at `ExecCtx::reserve` (plan time), never
/// mid-execution.
#[test]
fn ledger_peak_matches_workspace_plus_spectra_row() {
    let _g = guard();
    force_cache_mode(Some(CacheMode::Auto));
    let pool = tpool();
    let net = tiny_net(2);
    let cm = CostModel::default_rates(pool.workers());
    let mut space = SearchSpace::cpu_only(znni::device::Device::host_with_ram(4 << 30), 15);
    space.algos = vec![ConvAlgo::FftTaskParallel];
    space.max_candidates = 1;
    let plan = search(&net, &space, &cm).expect("feasible");
    assert!(plan.kernel_cache_bytes > 0, "plan must choose to cache under 4 GiB");
    let weights = make_weights(&net, 9);
    let cp = compile(&net, &plan, &weights).unwrap();
    let req = cp.workspace_req(pool.workers());
    assert_eq!(req.resident_bytes, plan.kernel_cache_bytes, "planned row == searched row");

    let input = Tensor5::random(plan.input, 10);
    let input_bytes = plan.input.bytes_f32();
    let (out, peak) = znni::memory::measure(|| {
        // Cold context *and* cache build inside the measured section:
        // the spectra register with the ledger like any allocation.
        let mut ctx = cp.make_ctx(&pool).expect("budget admits the plan");
        cp.run(input, &mut ctx)
    });
    assert_eq!(cp.kernel_cache_bytes(), plan.kernel_cache_bytes, "built == planned");
    let measured = peak + input_bytes;
    assert!(
        measured <= req.total() + input_bytes,
        "measured peak {measured} exceeds workspace {} + spectra row {} + input {input_bytes}",
        req.bytes,
        req.resident_bytes
    );
    assert_eq!(out.shape(), *plan.shapes.last().unwrap());

    // Undersized budget: rejected at reserve, before execution.
    let mut tiny_ctx = ExecCtx::with_budget(&pool, req.bytes / 2);
    let err = tiny_ctx.reserve(&req).expect_err("undersized budget must fail at plan time");
    assert!(err.to_string().contains("undersized"), "{err}");
    force_cache_mode(None);
}

/// Acceptance: `on` (force) mode caches every admissible FFT layer even
/// when the cost model would not bother, and the plan accounts for it.
#[test]
fn force_mode_caches_every_fft_layer() {
    let _g = guard();
    force_cache_mode(Some(CacheMode::Force));
    let net = tiny_net(2);
    let cm = CostModel::default_rates(2);
    let mut space = SearchSpace::cpu_only(znni::device::Device::host_with_ram(4 << 30), 15);
    space.algos = vec![ConvAlgo::FftDataParallel, ConvAlgo::FftTaskParallel];
    space.max_candidates = 2;
    let plan = search(&net, &space, &cm).expect("feasible");
    for l in &plan.layers {
        if let PlanLayer::Conv { algo, cache_kernels, .. } = l {
            assert!(algo.uses_kernel_cache());
            assert!(*cache_kernels, "force mode must cache every FFT layer");
        }
    }
    assert!(plan.kernel_cache_bytes > 0);
    assert!(plan.est_memory >= plan.kernel_cache_bytes);
    force_cache_mode(None);
}

/// The raw store: a CPU-layout cache and a GPU-layout cache for the
/// same weights are distinct allocations with the expected geometry.
#[test]
fn store_layouts_are_independent() {
    let _g = guard();
    let pool = tpool();
    let w = Weights::random(3, 2, [2, 2, 2], 13);
    let padded = [6, 6, 6];
    let cpu = PrecomputedKernels::build(&w, SpectraLayout::Cpu, padded, &pool);
    let gpu = PrecomputedKernels::build(&w, SpectraLayout::Gpu, padded, &pool);
    assert_eq!(cpu.layout(), SpectraLayout::Cpu);
    assert_eq!(gpu.layout(), SpectraLayout::Gpu);
    assert_eq!(cpu.padded(), padded);
    // Same element count per kernel (x̃·ỹ·(z̃/2+1) complex bins), so the
    // resident rows agree — the single `kernel_spectra_bytes` law.
    assert_eq!(cpu.bytes(), gpu.bytes());
    assert_eq!(cpu.spectrum(2, 1).len(), 6 * 6 * 4);
    assert_eq!(gpu.batch(2).len(), 2 * 6 * 6 * 4);
}
