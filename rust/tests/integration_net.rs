//! End-to-end: the four Table III architectures run through optimizer
//! plans and produce consistent results across primitive choices.

use std::sync::Arc;

use znni::conv::{conv_layer_reference, Activation, Weights};
use znni::device::Device;
use znni::exec::ExecCtx;
use znni::layers::{ConvLayer, LayerPrimitive};
use znni::memory::model::ConvAlgo;
use znni::net::zoo::{benchmark_nets, NetScale};
use znni::net::PoolingMode;
use znni::optimizer::{compile, make_weights, search, CostModel, SearchSpace};
use znni::tensor::{Shape5, Tensor5};
use znni::util::pool::{ChipTopology, TaskPool};
use znni::util::quick::assert_allclose;

fn tpool() -> TaskPool {
    TaskPool::with_topology(ChipTopology { chips: 2, cores_per_chip: 2 })
}

#[test]
fn all_benchmark_nets_execute_at_tiny_scale() {
    let pool = tpool();
    let mut ctx = ExecCtx::new(&pool);
    let cm = CostModel::default_rates(pool.workers());
    for net in benchmark_nets(NetScale::Tiny) {
        let modes = vec![PoolingMode::Mpf; net.pool_count()];
        let min = net.min_extent(&modes).unwrap();
        let mut space = SearchSpace::cpu_only(Device::host_with_ram(8 << 30), min + 16);
        space.min_extent = min;
        space.max_candidates = 1;
        let plan = search(&net, &space, &cm)
            .unwrap_or_else(|| panic!("{}: no feasible plan", net.name));
        let weights = make_weights(&net, 7);
        let cp = compile(&net, &plan, &weights).unwrap();
        let input = Tensor5::random(plan.input, 3);
        let out = cp.run(input, &mut ctx);
        assert_eq!(out.shape(), *plan.shapes.last().unwrap(), "{}", net.name);
        // The final conv layer has 3 output maps (affinity graph).
        assert_eq!(out.shape().f, 3, "{}", net.name);
        // MPF layers multiplied the batch by 8 per pool layer.
        assert_eq!(out.shape().s, 8usize.pow(net.pool_count() as u32), "{}", net.name);
    }
}

#[test]
fn every_conv_algo_agrees_on_a_net337_layer() {
    // Layer 3 of n337 at tiny scale: f = f' = 4, k = 3³.
    let pool = tpool();
    let mut ctx = ExecCtx::new(&pool);
    let w = Arc::new(Weights::random(4, 4, [3, 3, 3], 13));
    let input = Tensor5::random(Shape5::new(2, 4, 9, 9, 9), 17);
    let reference = conv_layer_reference(&input, &w, Activation::Relu);
    for algo in ConvAlgo::ALL {
        let layer = ConvLayer::new(w.clone(), algo, Activation::Relu);
        let out = layer.execute(input.clone_tensor(), &mut ctx);
        assert_allclose(out.data(), reference.data(), 1e-3, 1e-2, algo.name());
    }
}

#[test]
fn relu_applied_after_every_conv_layer() {
    let pool = tpool();
    let net = znni::net::zoo::tiny_net(4);
    let cm = CostModel::default_rates(pool.workers());
    let mut space = SearchSpace::cpu_only(Device::host_with_ram(8 << 30), 13);
    space.max_candidates = 1;
    let plan = search(&net, &space, &cm).unwrap();
    let weights = make_weights(&net, 3);
    let cp = compile(&net, &plan, &weights).unwrap();
    let mut ctx = ExecCtx::new(&pool);
    let out = cp.run(Tensor5::random(plan.input, 5), &mut ctx);
    assert!(out.data().iter().all(|&v| v >= 0.0));
}

#[test]
fn batch_concatenation_property_whole_net() {
    // §VII.B: net(concat(a, b)) == concat(net(a), net(b)).
    let pool = tpool();
    let net = znni::net::zoo::tiny_net(2);
    let cm = CostModel::default_rates(pool.workers());
    let mut space = SearchSpace::cpu_only(Device::host_with_ram(8 << 30), 13);
    space.max_candidates = 1;
    space.batch_sizes = vec![2];
    let plan = search(&net, &space, &cm).unwrap();
    assert_eq!(plan.input.s, 2);
    let weights = make_weights(&net, 9);
    let cp = compile(&net, &plan, &weights).unwrap();

    let a = Tensor5::random(Shape5 { s: 1, ..plan.input }, 100);
    let b = Tensor5::random(Shape5 { s: 1, ..plan.input }, 200);
    let mut cat = Tensor5::zeros(plan.input);
    cat.data_mut()[..a.data().len()].copy_from_slice(a.data());
    cat.data_mut()[a.data().len()..].copy_from_slice(b.data());

    let mut ctx = ExecCtx::new(&pool);
    let out_cat = cp.run(cat, &mut ctx);

    let mut space1 = space.clone();
    space1.batch_sizes = vec![1];
    let plan1 = search(&net, &space1, &cm).unwrap();
    let cp1 = compile(&net, &plan1, &weights).unwrap();
    let oa = cp1.run(a, &mut ctx);
    let ob = cp1.run(b, &mut ctx);

    let half = out_cat.data().len() / 2;
    assert_allclose(&out_cat.data()[..half], oa.data(), 1e-3, 1e-2, "first half");
    assert_allclose(&out_cat.data()[half..], ob.data(), 1e-3, 1e-2, "second half");
}
