//! NUMA placement and live replanning, end to end:
//!
//! * on a single-node host the NUMA path is a **provable no-op**:
//!   `ZNNI_NUMA=auto` makes zero pinning syscalls, produces outputs
//!   bit-identical to `off`, and still reaches the allocation-free
//!   steady state;
//! * a **live plan swap** under concurrent load answers every accepted
//!   request, re-converges to zero fresh allocations after the re-warm,
//!   and produces outputs bit-identical to a cold server started
//!   directly on the new plan (same weights, same function);
//! * the metrics-driven replanner arms, samples a serving server, and
//!   stops cleanly when the server drops.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use znni::conv::Weights;
use znni::device::Device;
use znni::memory::model::ConvAlgo;
use znni::net::NetSpec;
use znni::optimizer::{compile, make_weights, search, CostModel, Plan, SearchSpace};
use znni::server::replan::ReplanConfig;
use znni::server::{RejectReason, Server, ServerConfig, ServingLoad};
use znni::tensor::{Shape5, Tensor5};
use znni::util::numa::{self, NumaMode};
use znni::util::pool::{ChipTopology, TaskPool};

fn setup() -> (NetSpec, Plan, Vec<Arc<Weights>>, Arc<TaskPool>) {
    let net = znni::net::zoo::tiny_net(2);
    let cm = CostModel::default_rates(4);
    let mut space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 15);
    space.max_candidates = 2;
    let plan = search(&net, &space, &cm).expect("feasible plan");
    let weights = make_weights(&net, 77);
    let pool = Arc::new(TaskPool::with_topology(ChipTopology { chips: 2, cores_per_chip: 2 }));
    (net, plan, weights, pool)
}

/// An FFT-only plan for the same net — a genuinely different plan to
/// swap to (different algorithms, different arena shapes).
fn fft_plan(net: &NetSpec) -> Plan {
    let cm = CostModel::default_rates(4);
    let mut space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 15);
    space.max_candidates = 2;
    space.algos = vec![ConvAlgo::FftTaskParallel];
    search(net, &space, &cm).expect("feasible fft plan")
}

fn mk(seed: u64) -> Tensor5 {
    Tensor5::random(Shape5::new(1, 1, 20, 20, 20), seed)
}

/// Serve one fixed round of requests sequentially; returns the outputs.
fn serve_round(server: &Server, seeds: std::ops::Range<u64>) -> Vec<Tensor5> {
    seeds
        .map(|i| server.submit(mk(i)).expect("admitted").wait().expect("served").output)
        .collect()
}

/// Warm a server until one full round causes no fresh arena
/// allocations; panics if it never converges.
fn warm_to_steady_state(server: &Server, base_seed: u64) {
    let fresh = |server: &Server| -> u64 {
        server.metrics().per_shard.iter().map(|s| s.arena_fresh_allocs).sum()
    };
    for round in 0..12u64 {
        let before = fresh(server);
        let tickets: Vec<_> =
            (0..4u64).map(|i| server.submit(mk(base_seed + round * 10 + i)).unwrap()).collect();
        for t in tickets {
            t.wait().unwrap();
        }
        let all_served = server.metrics().per_shard.iter().all(|s| s.requests > 0);
        if round > 0 && all_served && fresh(server) == before {
            return;
        }
    }
    panic!("server never reached an allocation-free steady state");
}

#[test]
fn single_node_numa_placement_is_a_provable_noop() {
    let (net, plan, weights, pool) = setup();
    let pins_at_start = numa::pin_calls();

    // Baseline: NUMA explicitly off.
    numa::force_numa_mode(Some(NumaMode::Off));
    let cp = compile(&net, &plan, &weights).unwrap();
    let server = Server::start(net.clone(), cp, ServerConfig::default(), pool.clone()).unwrap();
    let out_off = serve_round(&server, 0..4);
    drop(server);

    // Same server under `auto`: on a single-node host placement must
    // not activate — same outputs, same (zero) syscalls, and the
    // allocation-free steady state still holds.
    numa::force_numa_mode(Some(NumaMode::Auto));
    let cp = compile(&net, &plan, &weights).unwrap();
    let server = Server::start(net.clone(), cp, ServerConfig::default(), pool).unwrap();
    let out_auto = serve_round(&server, 0..4);
    warm_to_steady_state(&server, 1000);
    drop(server);
    numa::force_numa_mode(None);

    for (i, (a, b)) in out_off.iter().zip(&out_auto).enumerate() {
        assert_eq!(a.data(), b.data(), "request {i}: auto diverged from off on a single node");
    }
    // pin_calls is process-global, so only assert it where the claim
    // holds unconditionally: a single-node topology must never pin.
    if !numa::topology().is_multi() {
        assert_eq!(
            numa::pin_calls(),
            pins_at_start,
            "single-node serving must make zero affinity syscalls"
        );
    }
}

#[test]
fn live_plan_swap_under_load_answers_everything_and_matches_cold_restart() {
    let (net, plan, weights, pool) = setup();
    let plan_b = fft_plan(&net);
    let cfg = ServerConfig { shards: 2, queue_depth: 8, ..ServerConfig::default() };
    let server = Server::start(
        net.clone(),
        compile(&net, &plan, &weights).unwrap(),
        cfg.clone(),
        pool.clone(),
    )
    .unwrap();

    // Clients hammer the server while the plan is swapped out from
    // under them. Every accepted request must be answered Ok — by
    // whichever plan admitted it.
    let stop = AtomicBool::new(false);
    let answered: u64 = std::thread::scope(|s| {
        let handles: Vec<_> = (0..3u64)
            .map(|c| {
                let server = &server;
                let stop = &stop;
                s.spawn(move || {
                    let mut served = 0u64;
                    let mut i = 0u64;
                    while !stop.load(Ordering::SeqCst) {
                        match server.submit(mk(c * 1000 + i)) {
                            Ok(t) => {
                                t.wait().expect("accepted request must be answered");
                                served += 1;
                            }
                            Err(rej) => {
                                assert!(
                                    matches!(
                                        rej.reason,
                                        RejectReason::QueueFull { .. }
                                            | RejectReason::MemoryPressure { .. }
                                    ),
                                    "unexpected rejection: {:?}",
                                    rej.reason
                                );
                                std::thread::sleep(Duration::from_micros(200));
                            }
                        }
                        i += 1;
                    }
                    served
                })
            })
            .collect();
        // Let the load establish, then cut over mid-flight.
        std::thread::sleep(Duration::from_millis(30));
        server.swap_plan(compile(&net, &plan_b, &weights).unwrap()).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        stop.store(true, Ordering::SeqCst);
        handles.into_iter().map(|h| h.join().unwrap()).sum()
    });
    assert!(answered > 0, "clients must have been served across the swap");
    let m = server.metrics();
    assert_eq!(m.plan_swaps, 1);
    assert_eq!(m.completed, answered, "no accepted request may be dropped by the cutover");

    // After the cutover the server must re-converge to the zero-alloc
    // steady state on the new plan's arenas.
    warm_to_steady_state(&server, 5000);

    // And the swapped-in plan must compute the same function as a cold
    // server started directly on plan B with the same weights.
    let out_live = serve_round(&server, 9000..9004);
    drop(server);
    let cold =
        Server::start(net.clone(), compile(&net, &plan_b, &weights).unwrap(), cfg, pool).unwrap();
    let out_cold = serve_round(&cold, 9000..9004);
    for (i, (a, b)) in out_live.iter().zip(&out_cold).enumerate() {
        assert_eq!(
            a.data(),
            b.data(),
            "request {i}: swapped-in plan diverged from a cold restart onto the same plan"
        );
    }
}

#[test]
fn replanner_arms_samples_and_stops_cleanly() {
    let (net, plan, weights, pool) = setup();
    let cm = CostModel::default_rates(4);
    let mut space = SearchSpace::cpu_only(Device::host_with_ram(4 << 30), 15);
    space.max_candidates = 2;
    let cp = compile(&net, &plan, &weights).unwrap();
    let mut server = Server::start(net.clone(), cp, ServerConfig::default(), pool).unwrap();
    let rcfg = ReplanConfig {
        window: 2,
        sustain: 2,
        hysteresis: 0.5,
        cooldown: 4,
        sample_every: Duration::from_millis(5),
    };
    server.start_replanner(space, cm, ServingLoad { clients: 3, volume_extent: 20 }, rcfg);
    // Serve while the replanner samples in the background; the metrics
    // stream it sees is the real one.
    for i in 0..4u64 {
        server.submit(mk(7000 + i)).unwrap().wait().unwrap();
    }
    std::thread::sleep(Duration::from_millis(25));
    // Drop must stop the sampler thread promptly (no hang, no panic).
    drop(server);
}
