"""Pallas kernels vs the pure-jnp oracle — the core build-time
correctness signal. Hypothesis sweeps shapes; fixed cases pin the
paper-relevant configurations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.conv3d import (
    conv3d_mxu_utilization,
    conv3d_pallas,
    conv3d_vmem_bytes,
)
from compile.kernels.mpf import mpf_pallas
from compile.kernels import ref


def rand(key, shape):
    return jax.random.uniform(key, shape, jnp.float32, -1.0, 1.0)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


@pytest.mark.parametrize("f_in,f_out,k,n", [
    (1, 8, 2, 9),     # n337 first layer shape family
    (4, 4, 3, 8),     # body layer
    (2, 3, 5, 11),    # n537 body kernel
    (1, 1, 1, 4),     # degenerate identity-size
    (3, 2, 4, 7),     # even kernel
])
def test_conv3d_pallas_matches_ref(f_in, f_out, k, n):
    ka, kb, kc = keys(0, 3)
    x = rand(ka, (f_in, n, n, n))
    w = rand(kb, (f_out, f_in, k, k, k))
    b = rand(kc, (f_out,))
    got = conv3d_pallas(x, w, b, relu=True)
    want = ref.conv3d_ref(x, w, b, relu=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    f_in=st.integers(1, 4),
    f_out=st.integers(1, 6),
    k=st.tuples(st.integers(1, 3), st.integers(1, 3), st.integers(1, 3)),
    extra=st.tuples(st.integers(0, 3), st.integers(0, 3), st.integers(0, 3)),
    relu=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_conv3d_pallas_hypothesis(f_in, f_out, k, extra, relu, seed):
    n = tuple(k[d] + extra[d] for d in range(3))
    ka, kb, kc = keys(seed, 3)
    x = rand(ka, (f_in,) + n)
    w = rand(kb, (f_out, f_in) + k)
    b = rand(kc, (f_out,))
    got = conv3d_pallas(x, w, b, relu=relu)
    want = ref.conv3d_ref(x, w, b, relu=relu)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv3d_is_true_convolution():
    """A kernel with a single 1 at index (0,0,0) must *shift* the image
    (flip semantics), not copy the leading window."""
    x = rand(keys(1, 1)[0], (1, 4, 4, 4))
    w = jnp.zeros((1, 1, 2, 2, 2)).at[0, 0, 0, 0, 0].set(1.0)
    b = jnp.zeros((1,))
    out = conv3d_pallas(x, w, b, relu=False)
    np.testing.assert_allclose(out[0], x[0, 1:, 1:, 1:], rtol=1e-6)


def test_conv3d_fout_block_padding():
    """f' not divisible by the block size exercises the pad/mask path."""
    ka, kb, kc = keys(2, 3)
    x = rand(ka, (3, 6, 6, 6))
    w = rand(kb, (5, 3, 3, 3, 3))
    b = rand(kc, (5,))
    got = conv3d_pallas(x, w, b, fout_block=4)
    want = ref.conv3d_ref(x, w, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("p,n", [
    ((2, 2, 2), (7, 7, 7)),
    ((2, 2, 2), (5, 9, 7)),
    ((3, 3, 3), (8, 8, 8)),
    ((2, 1, 1), (5, 4, 4)),   # the paper's 2x1x1 illustration window
])
def test_mpf_pallas_matches_ref(p, n):
    x = rand(keys(3, 1)[0], (3,) + n)
    got = mpf_pallas(x, p)
    want = ref.mpf_ref(x, p)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=0)


@settings(max_examples=15, deadline=None)
@given(
    f=st.integers(1, 3),
    p=st.sampled_from([(2, 2, 2), (3, 3, 3), (2, 1, 2)]),
    t=st.tuples(st.integers(1, 3), st.integers(1, 3), st.integers(1, 3)),
    seed=st.integers(0, 2**16),
)
def test_mpf_pallas_hypothesis(f, p, t, seed):
    n = tuple(p[d] * t[d] + p[d] - 1 for d in range(3))  # (n+1) % p == 0
    x = rand(keys(seed, 1)[0], (f,) + n)
    got = mpf_pallas(x, p)
    want = ref.mpf_ref(x, p)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=0)


def test_mpf_fragment_count_and_order():
    x = jnp.arange(1 * 5 * 5 * 5, dtype=jnp.float32).reshape(1, 5, 5, 5)
    out = mpf_pallas(x, (2, 2, 2))
    assert out.shape == (8, 1, 2, 2, 2)
    # Fragment 0 pools offsets (0,0,0); fragment 7 offsets (1,1,1).
    np.testing.assert_allclose(out[0], ref.maxpool_ref(x[:, :4, :4, :4], (2, 2, 2)))
    np.testing.assert_allclose(out[7], ref.maxpool_ref(x[:, 1:, 1:, 1:], (2, 2, 2)))


def test_vmem_estimate_within_budget():
    """The DESIGN.md §Perf claim: one grid step of the benchmark nets'
    largest layer fits a 16 MB VMEM."""
    # n337 body at paper scale, input patch 96^3 tile 24^3.
    vmem = conv3d_vmem_bytes((80, 24, 24, 24), (80, 80, 3, 3, 3))
    assert vmem <= 16 << 20, f"{vmem} bytes exceeds VMEM"


def test_mxu_utilization_estimate_monotone():
    low = conv3d_mxu_utilization((8, 8, 8, 8), (8, 8, 3, 3, 3), fout_block=8)
    high = conv3d_mxu_utilization((128, 8, 8, 8), (128, 128, 3, 3, 3), fout_block=128)
    assert 0 < low < high <= 1.0
