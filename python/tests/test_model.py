"""Layer-2 model tests: config parsing, shape propagation, whole-net
forward vs oracle composition, and batch/fragment ordering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def rand(seed, shape):
    return jax.random.uniform(jax.random.PRNGKey(seed), shape, jnp.float32, -1.0, 1.0)


def make_weights(f_in, layers, seed=0):
    ws = []
    for i, (wshape, bshape) in enumerate(model.weight_shapes(f_in, layers)):
        ws.append(rand(seed + 2 * i, wshape))
        ws.append(rand(seed + 2 * i + 1, bshape))
    return ws


def test_parse_tiny_net():
    f_in, layers = model.parse_net(model.TINY_NET)
    assert f_in == 1
    assert layers == [('conv', 4, (3, 3, 3)), ('pool', (2, 2, 2)),
                      ('conv', 4, (3, 3, 3)), ('conv', 2, (3, 3, 3))]


def test_parse_rejects_garbage():
    with pytest.raises(ValueError):
        model.parse_net("input 1\nwibble 3\n")
    with pytest.raises(ValueError):
        model.parse_net("conv 4 3\n")


def test_weight_shapes_track_channels():
    f_in, layers = model.parse_net(model.TINY_NET)
    shapes = model.weight_shapes(f_in, layers)
    assert shapes[0][0] == (4, 1, 3, 3, 3)
    assert shapes[1][0] == (4, 4, 3, 3, 3)
    assert shapes[2][0] == (2, 4, 3, 3, 3)


def test_net_forward_shape_and_fragments():
    f_in, layers = model.parse_net(model.TINY_NET)
    ws = make_weights(f_in, layers)
    x = rand(99, (1, 1, 13, 13, 13))
    out = model.net_forward(x, ws, layers, use_pallas=False)
    # 13 -> conv 11 -> MPF (8 frags of 5) -> conv 3 -> conv 1
    assert out.shape == (8, 2, 1, 1, 1)


def test_pallas_and_ref_paths_agree():
    f_in, layers = model.parse_net(model.TINY_NET)
    ws = make_weights(f_in, layers, seed=7)
    x = rand(5, (1, 1, 13, 13, 13))
    a = model.net_forward(x, ws, layers, use_pallas=True)
    b = model.net_forward(x, ws, layers, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_batch_concatenation_property():
    """§VII.B: applying the net to a concatenated batch equals
    concatenating per-input results (fragment groups stay contiguous)."""
    f_in, layers = model.parse_net(model.TINY_NET)
    ws = make_weights(f_in, layers, seed=3)
    x1 = rand(11, (1, 1, 13, 13, 13))
    x2 = rand(12, (1, 1, 13, 13, 13))
    both = jnp.concatenate([x1, x2], axis=0)
    o1 = model.net_forward(x1, ws, layers, use_pallas=False)
    o2 = model.net_forward(x2, ws, layers, use_pallas=False)
    ob = model.net_forward(both, ws, layers, use_pallas=False)
    np.testing.assert_allclose(ob, jnp.concatenate([o1, o2], axis=0), rtol=1e-5)


def test_mpf_layer_batch_order():
    """Fragment index must be least-significant in the output batch."""
    x = jnp.stack([
        jnp.zeros((1, 5, 5, 5), jnp.float32),
        jnp.ones((1, 5, 5, 5), jnp.float32),
    ])
    out = model.mpf_layer(x, (2, 2, 2), use_pallas=False)
    assert out.shape == (16, 1, 2, 2, 2)
    assert float(out[:8].max()) == 0.0
    assert float(out[8:].min()) == 1.0


def test_first_layer_config():
    f_in, layers = model.parse_net(model.FIRST_LAYER_N337)
    ws = make_weights(f_in, layers)
    x = rand(1, (1, 1, 9, 9, 9))
    out = model.net_forward(x, ws, layers, use_pallas=False)
    assert out.shape == (1, 8, 8, 8, 8)
    want = ref.conv3d_ref(x[0], ws[0], ws[1])
    np.testing.assert_allclose(out[0], want, rtol=1e-5, atol=1e-5)
