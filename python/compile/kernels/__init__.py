"""Layer-1 Pallas kernels + the pure-jnp oracle."""
