"""Layer-1 Pallas kernel: max-pooling fragments (MPF, paper §V).

For window p = (px, py, pz) the kernel emits all px·py·pz pooled
fragments of the input — the batch-multiplying pooling that lets a
sliding-window net reuse computation. Offsets are unrolled statically;
each fragment is a strided-window max, which on TPU is a VPU reduce
over a reshaped (x', px, y', py, z', pz) view (no gather needed).

interpret=True for the same reason as conv3d (see that module).
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mpf_kernel(i_ref, o_ref, *, p):
    """i_ref: (f, nx, ny, nz); o_ref: (P, f, x', y', z') with
    P = px·py·pz fragments in row-major offset order."""
    px, py, pz = p
    _, f, ox, oy, oz = o_ref.shape
    x = i_ref[...]
    frags = []
    for ax in range(px):
        for ay in range(py):
            for az in range(pz):
                win = jax.lax.dynamic_slice(
                    x, (0, ax, ay, az), (f, ox * px, oy * py, oz * pz)
                )
                v = win.reshape(f, ox, px, oy, py, oz, pz)
                frags.append(v.max(axis=(2, 4, 6)))
    o_ref[...] = jnp.stack(frags, axis=0)


def mpf_pallas(x, p):
    """MPF layer: x (f, n...) with (n+1) % p == 0 per dim →
    (P, f, n//p ...)."""
    f = x.shape[0]
    for d in range(3):
        assert (x.shape[1 + d] + 1) % p[d] == 0, "MPF needs (n+1) % p == 0"
    out_sp = tuple(x.shape[1 + d] // p[d] for d in range(3))
    bp = p[0] * p[1] * p[2]
    return pl.pallas_call(
        partial(_mpf_kernel, p=tuple(p)),
        grid=(1,),
        in_specs=[pl.BlockSpec(x.shape, lambda i: (0,) * 4)],
        out_specs=pl.BlockSpec((bp, f) + out_sp, lambda i: (0,) * 5),
        out_shape=jax.ShapeDtypeStruct((bp, f) + out_sp, jnp.float32),
        interpret=True,
    )(x)
