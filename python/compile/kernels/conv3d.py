"""Layer-1 Pallas kernel: 3D valid convolution (true convolution) with
bias + ReLU, tiled for the TPU MXU.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's GPU
hot spot is implicit-GEMM convolution tuned for threadblocks + shared
memory. On a TPU the same reuse is expressed by tiling the output so
each (output-channel block × input-channel block) contraction runs on
the MXU systolic array: for every kernel tap (a, b, c) the update

    O[j, x, y, z] += W[j, i, a, b, c] · I[i, x+a, y+b, z+c]

is a (f' × f) @ (f × XYZ) matmul — `jnp.einsum('ji,ixyz->jxyz')`
lowers to a single `dot_general` feeding the MXU. The grid iterates
over output-channel blocks; BlockSpecs express the HBM→VMEM schedule
(weights for one block + the full input window resident in VMEM).

The kernel is lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode is the
correctness path; real-TPU efficiency is estimated analytically in
DESIGN.md §Perf.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Output-channel block: 8 keeps the (bf' × f) tap matmuls MXU-shaped
# without exceeding VMEM for the benchmark nets' 80-map layers.
DEFAULT_FOUT_BLOCK = 8


def _conv3d_tap_kernel(i_ref, w_ref, b_ref, o_ref, *, k, relu):
    """One grid step: all taps for one output-channel block.

    i_ref: (f, x, y, z)        — full input window (VMEM)
    w_ref: (bf', f, kx, ky, kz) — weights for this block
    b_ref: (bf',)              — bias for this block
    o_ref: (bf', x', y', z')   — output tile
    """
    kx, ky, kz = k
    _, ox, oy, oz = o_ref.shape
    x = i_ref[...]
    w = w_ref[...]
    acc = jnp.zeros(o_ref.shape, dtype=jnp.float32)
    # Static unroll over kernel taps; each tap is one MXU contraction.
    for a in range(kx):
        for b in range(ky):
            for c in range(kz):
                win = jax.lax.dynamic_slice(
                    x, (0, a, b, c), (x.shape[0], ox, oy, oz)
                )
                # True convolution: flip the kernel indices.
                tap = w[:, :, kx - 1 - a, ky - 1 - b, kz - 1 - c]
                acc = acc + jnp.einsum(
                    "ji,ixyz->jxyz", tap, win, preferred_element_type=jnp.float32
                )
    acc = acc + b_ref[...][:, None, None, None]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def conv3d_pallas(x, w, bias, *, relu=True, fout_block=DEFAULT_FOUT_BLOCK):
    """Valid 3D convolution layer via the Pallas kernel.

    x: (f, nx, ny, nz); w: (f', f, kx, ky, kz); bias: (f',)
    returns (f', nx-kx+1, ny-ky+1, nz-kz+1)
    """
    f_out, f_in, kx, ky, kz = w.shape
    assert x.shape[0] == f_in, f"channel mismatch {x.shape[0]} vs {f_in}"
    out_sp = (x.shape[1] - kx + 1, x.shape[2] - ky + 1, x.shape[3] - kz + 1)
    bf = min(fout_block, f_out)
    # Pad f' up to a multiple of the block (masked off afterwards).
    f_pad = (-f_out) % bf
    if f_pad:
        w = jnp.pad(w, ((0, f_pad), (0, 0), (0, 0), (0, 0), (0, 0)))
        bias = jnp.pad(bias, (0, f_pad))
    blocks = (f_out + f_pad) // bf

    out = pl.pallas_call(
        partial(_conv3d_tap_kernel, k=(kx, ky, kz), relu=relu),
        grid=(blocks,),
        in_specs=[
            # Whole input window resident per step.
            pl.BlockSpec(x.shape, lambda j: (0,) * 4),
            # One output-channel block of weights per step.
            pl.BlockSpec((bf, f_in, kx, ky, kz), lambda j: (j, 0, 0, 0, 0)),
            pl.BlockSpec((bf,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((bf,) + out_sp, lambda j: (j, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(((f_out + f_pad),) + out_sp, jnp.float32),
        interpret=True,
    )(x, w, bias)
    return out[:f_out]


def conv3d_vmem_bytes(x_shape, w_shape, fout_block=DEFAULT_FOUT_BLOCK):
    """Analytic VMEM footprint of one grid step (bytes, f32): input
    window + weight block + output tile + accumulator."""
    f_in, nx, ny, nz = x_shape
    f_out, _, kx, ky, kz = w_shape
    bf = min(fout_block, f_out)
    out_sp = (nx - kx + 1) * (ny - ky + 1) * (nz - kz + 1)
    inp = f_in * nx * ny * nz
    wgt = bf * f_in * kx * ky * kz
    out = bf * out_sp
    return 4 * (inp + wgt + 2 * out)


def conv3d_mxu_utilization(x_shape, w_shape, fout_block=DEFAULT_FOUT_BLOCK):
    """Analytic MXU utilisation estimate of the tap matmuls: the
    contraction is (bf × f) @ (f × XYZ); the 128×128 MXU is fully fed
    when bf and f reach 128. Returns min(1, bf/128) · min(1, f/128)."""
    f_in = x_shape[0]
    f_out = w_shape[0]
    bf = min(fout_block, f_out)
    return min(1.0, bf / 128.0) * min(1.0, f_in / 128.0)
