"""Pure-jnp oracles for the Pallas kernels (the correctness anchor of
the build-time test suite).

Semantics match the paper and the Rust primitives exactly:
* convolution is *true* convolution (flipped kernel), "valid" region;
* MPF emits fragments in row-major offset order, batch-major.
"""

import jax.numpy as jnp
from jax import lax


def conv3d_ref(x, w, bias, relu=True):
    """x: (f, n...); w: (f', f, k...); bias: (f',)."""
    # lax convolution computes correlation; flip spatial axes for true
    # convolution (the paper's w * I).
    wf = w[:, :, ::-1, ::-1, ::-1]
    out = lax.conv_general_dilated(
        x[None],  # (1, f, nx, ny, nz)
        wf,
        window_strides=(1, 1, 1),
        padding="VALID",
        dimension_numbers=("NCHWD", "OIHWD", "NCHWD"),
    )[0]
    out = out + bias[:, None, None, None]
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def maxpool_ref(x, p):
    """x: (f, n...) with n % p == 0 -> (f, n/p...)."""
    f = x.shape[0]
    xr = x.reshape(
        f,
        x.shape[1] // p[0], p[0],
        x.shape[2] // p[1], p[1],
        x.shape[3] // p[2], p[2],
    )
    return xr.max(axis=(2, 4, 6))


def mpf_ref(x, p):
    """x: (f, n...) with (n+1) % p == 0 -> (P, f, n//p...)."""
    out_sp = tuple(x.shape[1 + d] // p[d] for d in range(3))
    frags = []
    for ax in range(p[0]):
        for ay in range(p[1]):
            for az in range(p[2]):
                win = x[:,
                        ax:ax + out_sp[0] * p[0],
                        ay:ay + out_sp[1] * p[1],
                        az:az + out_sp[2] * p[2]]
                frags.append(maxpool_ref(win, p))
    return jnp.stack(frags, axis=0)
