"""Build-time compile path (never imported at runtime)."""
