"""AOT lowering: JAX/Pallas graphs -> HLO text artifacts + manifest.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla`
crate's xla_extension 0.5.1 rejects; the text parser reassigns ids.
(See /opt/xla-example/README.md.)

Artifacts (all with weights as runtime inputs):
* conv_probe    — the Pallas conv3d kernel alone, small shape;
* tiny_net13    — the tiny CPCC net on a 13^3 patch (quickstart / tests);
* first_layer   — n337's first conv layer, the layer the CPU-GPU
                  pipeline offloads to the device (S = f = 1).

Run: python -m compile.aot --out ../artifacts  (or via `make artifacts`)
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import FIRST_LAYER_N337, TINY_NET, make_forward_fn, parse_net, weight_shapes


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_net(config_text, input_shape, use_pallas=True):
    """Lower a net forward to HLO text. Returns (text, arg_shapes,
    out_shape)."""
    fn, f_in, layers = make_forward_fn(config_text, use_pallas)
    assert input_shape[1] == f_in
    args = [jax.ShapeDtypeStruct(input_shape, jnp.float32)]
    for ws, bs in weight_shapes(f_in, layers):
        args.append(jax.ShapeDtypeStruct(ws, jnp.float32))
        args.append(jax.ShapeDtypeStruct(bs, jnp.float32))
    lowered = jax.jit(fn).lower(*args)
    out_shape = jax.eval_shape(fn, *args)[0].shape
    return to_hlo_text(lowered), [tuple(a.shape) for a in args], tuple(out_shape)


ARTIFACTS = [
    # (name, config, input shape (S, f, n, n, n), use_pallas)
    ("conv_probe", FIRST_LAYER_N337, (1, 1, 12, 12, 12), True),
    ("tiny_net13", TINY_NET, (1, 1, 13, 13, 13), True),
    ("first_layer", FIRST_LAYER_N337, (1, 1, 24, 24, 24), True),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = []
    for name, config, ishape, use_pallas in ARTIFACTS:
        text, arg_shapes, out_shape = lower_net(config, ishape, use_pallas)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest.append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "net": " ".join(config.split()),
                "arg_shapes": arg_shapes,
                "output_shape": list(out_shape),
                "pallas": use_pallas,
            }
        )
        print(f"wrote {path} ({len(text)} chars), out={out_shape}")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # Line-oriented twin of the manifest for the Rust loader (the
    # offline crate set has no JSON parser):
    #   artifact <name> <file>
    #   arg <d0> <d1> ...
    #   out <d0> <d1> ...
    with open(os.path.join(args.out, "manifest.txt"), "w") as f:
        for m in manifest:
            f.write(f"artifact {m['name']} {m['file']}\n")
            for sh in m["arg_shapes"]:
                f.write("arg " + " ".join(str(d) for d in sh) + "\n")
            f.write("out " + " ".join(str(d) for d in m["output_shape"]) + "\n")
    print(f"wrote {os.path.join(args.out, 'manifest.txt')}")


if __name__ == "__main__":
    main()
