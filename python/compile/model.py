"""Layer-2: the JAX model — ConvNet forward graphs built from the
Pallas kernels (L1), shared with the Rust coordinator through the same
tiny net-config format (`name/input/conv/pool` directives).

Weights are *runtime inputs* of the lowered functions (not baked
constants), so the Rust side feeds the exact same tensors to the PJRT
executable and to its native primitives and cross-checks the numerics.

Conventions (must match rust/src/):
* a batch is the leading axis: x is (S, f, nx, ny, nz);
* weights per conv layer: (f', f, kx, ky, kz) + bias (f',);
* true convolution (flipped kernels) + bias + ReLU on every conv layer;
* MPF fragments multiply the batch axis, fragment index is the
  least-significant part (s' = s * P + frag), offsets row-major.
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.conv3d import conv3d_pallas
from .kernels.mpf import mpf_pallas


def parse_net(text):
    """Parse the shared config format. Returns (f_in, layers) where
    layers are ('conv', f_out, (k,k,k)) / ('pool', (p,p,p))."""
    f_in = None
    layers = []
    for raw in text.splitlines():
        line = raw.split('#')[0].strip()
        if not line:
            continue
        toks = line.split()
        if toks[0] == 'name':
            continue
        elif toks[0] == 'input':
            f_in = int(toks[1])
        elif toks[0] == 'conv':
            nums = [int(t) for t in toks[1:]]
            f_out = nums[0]
            k = tuple(nums[1:]) if len(nums) == 4 else (nums[1],) * 3
            layers.append(('conv', f_out, k))
        elif toks[0] == 'pool':
            nums = [int(t) for t in toks[1:]]
            p = tuple(nums) if len(nums) == 3 else (nums[0],) * 3
            layers.append(('pool', p))
        else:
            raise ValueError(f'unknown directive {toks[0]}')
    if f_in is None or not layers:
        raise ValueError('config needs input + layers')
    return f_in, layers


def weight_shapes(f_in, layers):
    """Shapes of the (w, b) pairs the forward function expects."""
    shapes = []
    f = f_in
    for l in layers:
        if l[0] == 'conv':
            _, f_out, k = l
            shapes.append(((f_out, f) + k, (f_out,)))
            f = f_out
    return shapes


def conv_layer(x, w, b, use_pallas=True):
    """Batched conv layer: x (S, f, n...)."""
    fn = conv3d_pallas if use_pallas else ref.conv3d_ref
    return jax.vmap(lambda xi: fn(xi, w, b))(x)


def mpf_layer(x, p, use_pallas=True):
    """Batched MPF layer: (S, f, n...) -> (S·P, f, n//p...)."""
    fn = mpf_pallas if use_pallas else ref.mpf_ref
    frags = jax.vmap(lambda xi: fn(xi, p))(x)  # (S, P, f, ...)
    s, pcount = frags.shape[0], frags.shape[1]
    return frags.reshape((s * pcount,) + frags.shape[2:])


def net_forward(x, weights, layers, use_pallas=True):
    """Run the whole net. `weights` is the flat [w1, b1, w2, b2, ...]
    list in conv-layer order."""
    wi = 0
    for l in layers:
        if l[0] == 'conv':
            x = conv_layer(x, weights[wi], weights[wi + 1], use_pallas)
            wi += 2
        else:
            x = mpf_layer(x, l[1], use_pallas)
    return x


def make_forward_fn(config_text, use_pallas=True):
    """Returns (fn, f_in, layers); fn(x, *weights) -> output."""
    f_in, layers = parse_net(config_text)

    def fn(x, *weights):
        return (net_forward(x, list(weights), layers, use_pallas),)

    return fn, f_in, layers


# The tiny CPCC net shared with rust::net::zoo::tiny_net(4).
TINY_NET = """
name tiny-cpcc
input 1
conv 4 3
pool 2
conv 4 3
conv 2 3
"""

# First layer of n337 at Small scale (8 maps), the shape the paper
# finds FFT-DP/CuDNN1-optimal (f = S = 1).
FIRST_LAYER_N337 = """
name n337-first
input 1
conv 8 2
"""
